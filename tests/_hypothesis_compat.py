"""Property-test shim: real hypothesis when installed, otherwise a tiny
deterministic fallback sampler.

The pinned toolchain image does not ship hypothesis and tier-1 must collect
cleanly without it; skipping the property tests outright would silently drop
coverage, so the fallback draws ``max_examples`` pseudo-random samples from
the declared strategies with a fixed seed instead (no shrinking, no database
— just execution).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # type: ignore[no-redef]
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # no functools.wraps: pytest must see the zero-arg signature,
            # not the strategy parameters (it would demand fixtures for them)
            def run(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run

        return deco


strategies = st
