"""The `repro.audit.ranges` / `repro.audit.interp` range-certificate pass.

Covers the interval interpreter (transfer functions, control-flow
fixpoints, the signed-only flagging policy), the closed-form per-plan
certificates against brute-force empirical accumulators (property tests
over family x format x radix), the planner's certificate gate (a
crafted wide int16 TL1 plan must be rejected loudly; the symmetric
narrow plan must pass and come back stamped), the trace-time kernel
contract assert, and the seeded-overflow regression through
``overflow_violations``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.audit.interp import (
    INT_INPUT_BOUND,
    Interval,
    default_arg_intervals,
    interval_eval,
)
from repro.audit.ranges import layer_range_cert, overflow_violations
from repro.core.lut import (
    LUTPlan,
    apply_luts,
    build_luts,
    pack_codes,
    quantize_tables,
)
from repro.core.lut_tl1 import (
    TL1Plan,
    _accumulate,
    build_act_lut,
    pack_ternary,
    quantize_acts,
    unpack_indices,
)
from repro.core.planner import ModelPlan, plan_model
from repro.core.quantize import Float16Format
from repro.kernels.common import acc_capacity, check_acc_contract

# ---------------------------------------------------------------------------
# interval interpreter
# ---------------------------------------------------------------------------


def test_default_arg_intervals_policy():
    jaxpr = jax.make_jaxpr(lambda a, b, c: (a, b, c))(
        jnp.zeros((2,), jnp.int32),
        jnp.zeros((2,), jnp.int8),
        jnp.zeros((2,), jnp.float32),
    )
    i32, i8, f32 = default_arg_intervals(jaxpr)
    assert i32 == Interval(-float(INT_INPUT_BOUND), float(INT_INPUT_BOUND))
    assert i8 == Interval(-128.0, 127.0)  # dtype range tighter than the bound
    assert f32.lo == -np.inf and f32.hi == np.inf


def test_in_range_int_arithmetic_is_clean():
    jaxpr = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros((4,), jnp.int32))
    outs, facts = interval_eval(jaxpr)
    assert facts == []
    assert outs[0].within(Interval(-(2.0**24) + 1, 2.0**24 + 1))


def test_seeded_int16_add_overflow_fires():
    # int16 inputs span the full dtype range; x + x escapes it ideally
    jaxpr = jax.make_jaxpr(lambda x: x + x)(jnp.zeros((4,), jnp.int16))
    _, facts = interval_eval(jaxpr)
    assert facts and facts[0].primitive == "add"
    assert "escapes" in facts[0].detail
    assert facts[0].dtype == "int16"


def test_unsigned_wrap_is_never_flagged():
    # threefry-style uint arithmetic wraps by design
    jaxpr = jax.make_jaxpr(lambda x: x + x)(jnp.zeros((4,), jnp.uint32))
    outs, facts = interval_eval(jaxpr)
    assert facts == []
    assert outs[0].within(Interval(0.0, float(2**32 - 1)))


def test_convert_element_type_narrows_without_flagging():
    jaxpr = jax.make_jaxpr(lambda x: x.astype(jnp.int16))(
        jnp.zeros((4,), jnp.int32)
    )
    outs, facts = interval_eval(jaxpr)
    assert facts == []
    assert outs[0].within(Interval(-32768.0, 32767.0))


def test_scan_fixpoint_converges_on_bounded_carry():
    def f(x):
        def body(c, _):
            return jnp.minimum(c + 1, 3), None

        y, _ = jax.lax.scan(body, x, None, length=100)
        return y

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((), jnp.int32))
    outs, facts = interval_eval(
        jaxpr, [Interval.point(0.0)]
    )
    assert facts == []
    assert outs[0].within(Interval(0.0, 4.0))


def test_scan_accumulator_overflow_fires_after_widening():
    # an unbounded int32 running sum cannot converge: the carry widens to
    # the dtype range and the final unmuted pass flags the add
    def f(x, xs):
        def body(c, v):
            return c + v, None

        y, _ = jax.lax.scan(body, x, xs)
        return y

    jaxpr = jax.make_jaxpr(f)(
        jnp.zeros((), jnp.int32), jnp.zeros((8,), jnp.int32)
    )
    _, facts = interval_eval(jaxpr)
    assert any(f.primitive == "add" for f in facts)


def test_dot_general_contraction_scales_by_width():
    jaxpr = jax.make_jaxpr(lambda a, b: a @ b)(
        jnp.zeros((2, 16), jnp.float32), jnp.zeros((16, 3), jnp.float32)
    )
    outs, _ = interval_eval(
        jaxpr, [Interval(-1.0, 1.0), Interval(-1.0, 1.0)]
    )
    assert outs[0].within(Interval(-16.0, 16.0))
    assert outs[0].mag >= 16.0  # the bound is tight for +/-1 operands


# ---------------------------------------------------------------------------
# closed-form certificates
# ---------------------------------------------------------------------------


def test_weight_cert_fp16_full_uses_format_max():
    plan = LUTPlan(8, 4, 1, Float16Format(), mode="full")
    cert = layer_range_cert(plan)
    assert cert.family == "weight" and not cert.integer
    assert cert.max_abs_acc == pytest.approx(8 * 65504.0)
    assert cert.table_quant_err == 0.0
    assert cert.min_acc_dtype == "float32"


def test_weight_cert_bitplane_shift_radix1_matches_format_max():
    # 32 * (2**(1*11) - 1) == 65504: the radix-1 bound is exactly tight
    plan = LUTPlan(8, 4, 1, Float16Format(), mode="bitplane_shift")
    cert = layer_range_cert(plan)
    assert cert.max_abs_acc == pytest.approx(8 * 65504.0)


def test_weight_cert_narrow_format_adds_quant_terms():
    base = LUTPlan(8, 4, 1, Float16Format(), mode="bitplane_shift")
    narrow = dataclasses.replace(base, table_format="i8")
    cb, cn = layer_range_cert(base), layer_range_cert(narrow)
    assert cn.max_abs_acc == pytest.approx(cb.max_abs_acc * (1 + 1 / 127))
    assert cn.table_quant_err == pytest.approx(cb.max_abs_acc / 127)
    assert cn.total_err > cb.total_err


def test_tl1_cert_int_path_counts_code_units():
    plan = TL1Plan(4096, 64, act_bits=8)
    cert = layer_range_cert(plan)
    assert cert.family == "tl1" and cert.integer
    assert cert.entry_max == 254.0  # 2 * (2**7 - 1)
    assert cert.max_abs_acc == 254.0 * plan.num_chunks
    assert cert.min_acc_dtype == "int32"  # 520192 > int16
    assert cert.table_quant_err == 0.0


def test_tl1_cert_exact_path_is_float_and_errorless():
    plan = TL1Plan(4096, 64, act_bits=None)
    cert = layer_range_cert(plan)
    assert not cert.integer
    assert plan.acc_dtype == "float32"  # __post_init__ normalises
    assert cert.total_err == 0.0


# ---------------------------------------------------------------------------
# property tests: empirical |acc| never exceeds the static bound
# ---------------------------------------------------------------------------


@settings(max_examples=15)
@given(
    q=st.sampled_from([5, 24, 64]),
    act_bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tl1_empirical_acc_within_static_bound(q, act_bits, seed):
    p = 8
    plan = TL1Plan(q, p, act_bits=act_bits)
    cert = layer_range_cert(plan)
    rng = np.random.default_rng(seed)
    # adversarial-leaning inputs: full-scale activations, dense ternary
    x = jnp.asarray(rng.uniform(-1.0, 1.0, size=(4, q)), jnp.float32)
    t = jnp.asarray(rng.choice([-1, 0, 1], size=(q, p), p=[0.45, 0.1, 0.45]))
    codes, _ = quantize_acts(x, plan)
    acc = _accumulate(build_act_lut(codes), unpack_indices(pack_ternary(t)))
    assert float(jnp.max(jnp.abs(acc))) <= cert.max_abs_acc
    # ...and the per-entry LUT bound holds too
    lut = build_act_lut(codes)
    assert float(jnp.max(jnp.abs(lut))) <= cert.entry_max


@settings(max_examples=10)
@given(
    radix=st.sampled_from([1, 2, 4]),
    table_format=st.sampled_from(["i8", "i16"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_weight_empirical_acc_within_static_bound(radix, table_format, seed):
    q, p = 24, 8
    plan = LUTPlan(
        q,
        p,
        1,
        Float16Format(mantissa_radix=radix),
        mode="bitplane_shift",
        table_format=table_format,
    )
    cert = layer_range_cert(plan)
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.uniform(-1.0, 1.0, size=(q, p)), jnp.float32)
    x = jnp.asarray(rng.uniform(0.0, 1.0, size=(4, q)), jnp.float32)
    narrow, scale = quantize_tables(build_luts(W, plan), table_format)
    dequant = narrow.astype(jnp.float32) * scale
    acc = apply_luts(dequant, pack_codes(x, plan), plan)
    assert float(jnp.max(jnp.abs(acc))) <= cert.max_abs_acc


# ---------------------------------------------------------------------------
# planner gate + kernel contract + overflow rule class
# ---------------------------------------------------------------------------

_WIDE = dict(in_features=4096, out_features=64)  # 2048 chunks: |acc| > int16
_NARROW = dict(in_features=64, out_features=16)  # 32 chunks: fits int16


def _params(q, p):
    return {"ffn": {"w": jax.ShapeDtypeStruct((q, p), jnp.float32)}}


def test_planner_rejects_unprovable_tl1_acc_dtype():
    with pytest.raises(ValueError, match="no overflow-safe plan"):
        plan_model(
            _params(4096, 64),
            float("inf"),
            families=("tl1",),
            tl1_acc_dtype="int16",
        )


def test_planner_stamps_provably_safe_plans():
    mplan = plan_model(
        _params(64, 16),
        float("inf"),
        families=("tl1",),
        tl1_acc_dtype="int16",
    )
    ((key, plan),) = mplan.layers.items()
    assert plan.acc_dtype == "int16"
    cert = layer_range_cert(plan)
    assert plan.max_abs_acc == cert.max_abs_acc
    assert cert.max_abs_acc <= acc_capacity("int16")
    # the stamp survives a JSON round trip (checkpoint path)
    rt = ModelPlan.from_json(mplan.to_json())
    assert rt.layers[key].max_abs_acc == plan.max_abs_acc
    assert rt.layers[key].acc_dtype == "int16"


def test_stamp_is_excluded_from_plan_equality():
    plan = TL1Plan(**_NARROW, act_bits=8)
    stamped = dataclasses.replace(plan, max_abs_acc=8128.0)
    assert stamped == plan  # derived metadata, like a cache
    assert dataclasses.replace(plan, acc_dtype="int16") != plan


def test_check_acc_contract_raises_on_forged_bound():
    plan = TL1Plan(**_NARROW, act_bits=8, acc_dtype="int16")
    ok = dataclasses.replace(plan, max_abs_acc=8128.0)
    check_acc_contract("lut_tl1", ok, "int32")  # declared + kernel both fit
    forged = dataclasses.replace(plan, max_abs_acc=1e6)
    with pytest.raises(ValueError, match="capacity"):
        check_acc_contract("lut_tl1", forged, "int32")
    wide_ok = dataclasses.replace(
        TL1Plan(**_WIDE, act_bits=8), max_abs_acc=520192.0
    )
    with pytest.raises(ValueError, match="too narrow"):
        check_acc_contract("lut_tl1", wide_ok, "int16")
    # no stamp -> no-op (pre-contract plans keep tracing)
    check_acc_contract("lut_tl1", TL1Plan(**_NARROW), "int32")


def test_overflow_violations_fire_on_crafted_wide_int16_plan():
    wide = TL1Plan(**_WIDE, act_bits=8, acc_dtype="int16")
    hits = overflow_violations(ModelPlan(layers={"ffn/w": wide}))
    kinds = {v.primitive for v in hits}
    assert "accumulate" in kinds
    assert all(v.rule == "overflow" for v in hits)
    # the symmetric narrow plan is clean under the identical predicate
    narrow = TL1Plan(**_NARROW, act_bits=8, acc_dtype="int16")
    assert overflow_violations(ModelPlan(layers={"ffn/w": narrow})) == []


def test_overflow_violations_flag_stale_stamp():
    plan = dataclasses.replace(TL1Plan(**_NARROW, act_bits=8), max_abs_acc=1.0)
    hits = overflow_violations(ModelPlan(layers={"ffn/w": plan}))
    assert any(v.primitive == "stale_bound" for v in hits)


def test_overflow_violations_walk_named_graphs():
    mplan = ModelPlan(layers={"ffn/w": TL1Plan(**_NARROW, act_bits=8)})
    bad = jax.make_jaxpr(lambda x: x + x)(jnp.zeros((4,), jnp.int16))
    hits = overflow_violations(mplan, graphs=(("decode", bad),))
    assert any(
        v.primitive == "add" and v.detail.startswith("decode:") for v in hits
    )
    clean = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros((4,), jnp.int32))
    assert overflow_violations(mplan, graphs=(("decode", clean),)) == []
