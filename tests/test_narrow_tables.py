"""Narrow (int8/int16) table storage and bitplane_shift exponent codes.

Three layers of evidence, matching the execution stack:

* ``quantize_tables`` semantics — power-of-2 scales, per-table-set
  ``trailing`` shapes (the leaf must stay layer-scan sliceable), and the
  dequant error bound.
* Pallas kernels (interpret mode) vs the jnp oracle across a shape grid,
  for i8/i16 tables and for ``shift_bits`` exponent-carrying codes, on the
  single / grouped / experts entry points.
* The ``bitplane_shift`` mode end to end: radix-r mantissa planes with the
  sigma barrel-shift applied at accumulate reproduce the fp16 matmul, and
  stay accurate after i8 table quantization (the whole point of the mode:
  sigma-free tables span only ``[-(2**r-1), 2**r-1]``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lut import (
    LUTPlan,
    apply_luts,
    build_luts,
    lut_affine_reference,
    pack_codes,
    plane_scales,
    quantize_tables,
    table_scale,
)
from repro.core.quantize import Float16Format
from repro.kernels.lut_affine.ops import (
    lut_affine,
    lut_affine_experts,
    lut_affine_grouped,
)
from repro.kernels.lut_affine.ref import (
    lut_affine_experts_ref,
    lut_affine_grouped_ref,
    lut_affine_ref,
)

pytestmark = pytest.mark.slow  # interpret-mode Pallas sweeps


# ---------------------------------------------------------------------------
# quantize_tables / table_scale
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt,qmax", [("i8", 127), ("i16", 32767)])
def test_quantize_tables_pow2_scale_and_error_bound(fmt, qmax):
    tables = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 8)) * 3.0
    q, scale = quantize_tables(tables, fmt)
    assert q.dtype == (jnp.int8 if fmt == "i8" else jnp.int16)
    s = float(scale)
    assert s == 2.0 ** round(np.log2(s))  # power of two: folding is a shift
    assert float(jnp.abs(q).max()) <= qmax
    # dequant error is at most half a quantization step
    err = np.abs(np.asarray(q, np.float32) * s - np.asarray(tables))
    assert err.max() <= s / 2 + 1e-7


def test_table_scale_trailing_shapes():
    # (L, G, k, E, p): trailing=4 covers one grouped set; the leading scan
    # dim L keeps per-entry scales so lax.scan can slice the leaf
    tables = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 4, 8, 5))
    assert table_scale(tables, "i8", trailing=4).shape == (3,)
    assert table_scale(tables, "i8", trailing=3).shape == (3, 2)
    assert table_scale(tables, "i8").shape == ()  # None: whole-leaf scalar
    q, scale = quantize_tables(tables, "i8", trailing=4)
    assert scale.shape == (3,)
    for i in range(3):
        want = np.asarray(tables[i])
        got = np.asarray(q[i], np.float32) * float(scale[i])
        assert np.abs(got - want).max() <= float(scale[i]) / 2 + 1e-7


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle: narrow tables
# ---------------------------------------------------------------------------

_GRID = [
    (1, 1, 1, 2, 1),  # degenerate minimum
    (4, 3, 7, 8, 10),  # ragged everything
    (16, 3, 32, 32, 96),  # bitplane_shift-style planes
    (3, 2, 130, 16, 130),  # k and p beyond one block
]


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
@pytest.mark.parametrize("B,n,k,E,p", _GRID)
def test_lut_affine_narrow_matches_ref(B, n, k, E, p, dtype):
    kc, kt = jax.random.split(jax.random.PRNGKey(B * 13 + k))
    codes = jax.random.randint(kc, (B, n, k), 0, E)
    lim = int(jnp.iinfo(dtype).max)
    tables = jax.random.randint(kt, (k, E, p), -lim, lim, jnp.int32).astype(dtype)
    scales = 2.0 ** -jnp.arange(n, dtype=jnp.float32)  # dequant scale folded in
    got = lut_affine(codes, tables, scales, interpret=True)
    want = lut_affine_ref(codes, tables, scales)
    rel = 1e-5
    atol = rel * float(np.abs(np.asarray(want)).max() + 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rel, atol=atol)


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
def test_grouped_and_experts_narrow_match_ref(dtype):
    G, B, n, k, E, p = 3, 5, 2, 9, 16, 33
    kc, kt = jax.random.split(jax.random.PRNGKey(7))
    codes = jax.random.randint(kc, (B, n, k), 0, E)
    lim = int(jnp.iinfo(dtype).max)
    tables = jax.random.randint(kt, (G, k, E, p), -lim, lim, jnp.int32).astype(dtype)
    scales = jnp.asarray([1.0, 0.25])
    got = lut_affine_grouped(codes, tables, scales, interpret=True)
    want = lut_affine_grouped_ref(codes, tables, scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)

    NE, T = 2, 6
    etables = jnp.stack([tables, tables[::-1]])  # (NE, G, k, E, p)
    ecodes = jax.random.randint(jax.random.PRNGKey(8), (T, n, k), 0, E)
    group_sizes = jnp.asarray([4, 2], jnp.int32)
    got = lut_affine_experts(ecodes, etables, scales, group_sizes, interpret=True)
    want = lut_affine_experts_ref(ecodes, etables, scales, group_sizes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle: shift_bits (bitplane_shift contract)
# ---------------------------------------------------------------------------


def _shift_codes(key, shape, index_bits):
    """Packed codes: low index_bits = table index, high bits = fp16 exponent."""
    kf, ke = jax.random.split(key)
    field = jax.random.randint(kf, shape, 0, 2**index_bits)
    exp = jax.random.randint(ke, shape, 1, 13)  # sane sigma range
    return field | (exp << index_bits)


@pytest.mark.parametrize("B,n,k,E,p", [(4, 3, 7, 32, 10), (9, 3, 130, 32, 130)])
def test_lut_affine_shift_bits_matches_ref(B, n, k, E, p):
    index_bits = 5
    assert E == 2**index_bits
    kc, kt = jax.random.split(jax.random.PRNGKey(B + k))
    codes = _shift_codes(kc, (B, n, k), index_bits)
    tables = jax.random.randint(kt, (k, E, p), -15, 16, jnp.int32).astype(jnp.int8)
    scales = 2.0 ** (4.0 * jnp.arange(n, dtype=jnp.float32))  # radix-4 planes
    got = lut_affine(codes, tables, scales, shift_bits=index_bits, interpret=True)
    want = lut_affine_ref(codes, tables, scales, shift_bits=index_bits)
    rel = 1e-5
    atol = rel * float(np.abs(np.asarray(want)).max() + 1e-30)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rel, atol=atol)


def test_grouped_shift_bits_matches_ref():
    index_bits, G, B, n, k, p = 5, 2, 4, 3, 16, 40
    E = 2**index_bits
    kc, kt = jax.random.split(jax.random.PRNGKey(3))
    codes = _shift_codes(kc, (B, n, k), index_bits)
    tables = jax.random.randint(kt, (G, k, E, p), -15, 16, jnp.int32).astype(jnp.int8)
    scales = 2.0 ** (4.0 * jnp.arange(n, dtype=jnp.float32))
    got = lut_affine_grouped(
        codes, tables, scales, shift_bits=index_bits, interpret=True
    )
    want = lut_affine_grouped_ref(codes, tables, scales, shift_bits=index_bits)
    rel = 1e-5
    atol = rel * float(np.abs(np.asarray(want)).max() + 1e-30)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rel, atol=atol)


# ---------------------------------------------------------------------------
# bitplane_shift mode end to end
# ---------------------------------------------------------------------------


def test_bitplane_shift_matches_fp16_matmul():
    """Radix-4 mantissa planes + sigma-at-accumulate == the fp16 affine map."""
    fmt = Float16Format(signed=True, mantissa_radix=4)
    q, p = 64, 24
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    W = jax.random.normal(k1, (q, p)) / np.sqrt(q)
    x = jax.random.normal(k2, (8, q)) * 2.0
    plan = LUTPlan(q, p, 1, fmt, mode="bitplane_shift")
    assert len(plane_scales(plan)) == 3  # ceil(11 / 4) mantissa planes
    got = lut_affine_reference(x, W, None, plan)
    want = fmt.quantize(x).astype(jnp.float32) @ W
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-6)


def test_bitplane_shift_tables_survive_i8_quantization():
    """Sigma-free table entries span only small integers times W-columns, so
    i8 storage keeps the result close — the property that makes the narrow
    frontier numerically safe (sigma-laden tables lose ~everything)."""
    fmt = Float16Format(signed=True, mantissa_radix=4)
    q, p = 64, 24
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    W = jax.random.normal(k1, (q, p)) / np.sqrt(q)
    x = jax.random.normal(k2, (8, q)) * 2.0
    plan = LUTPlan(q, p, 1, fmt, mode="bitplane_shift", table_format="i8")
    tables = build_luts(W, plan)
    qt, scale = quantize_tables(tables, "i8")
    codes = pack_codes(x, plan)
    scales = jnp.asarray(plane_scales(plan), jnp.float32) * scale
    got = apply_luts(qt, codes, plan, scales=scales)
    want = fmt.quantize(x).astype(jnp.float32) @ W
    # same bar as the planner's convert-equivalence check; sigma-laden
    # tables fail this by ~50x (rel err ~1.0), sigma-free pass easily
    denom = np.abs(np.asarray(want)).max() + 1e-6
    assert np.abs(np.asarray(got) - np.asarray(want)).max() / denom < 0.05
