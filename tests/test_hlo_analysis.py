"""Unit tests for the roofline HLO parser + skip rules + mesh contract."""
import pytest

from repro.configs.base import get_config
from repro.launch import hlo_analysis as H
from repro.launch.inputs import cell_is_runnable, shape_case


HLO = (
    "%all-gather = f32[8192,8]{1,0} all-gather(%x), "
    "replica_groups=[4,4]<=[4,4]T(1,0), dimensions={0}\n"
    "%all-reduce.5 = bf16[1024]{0} all-reduce(%y), replica_groups=[2,8]<=[16]\n"
    "%tuple-ar = (f32[16384]{0}, f32[16384,256]{1,0}) all-reduce(%a, %b), "
    "replica_groups=[4,4]<=[4,4]T(1,0)\n"
    "%rs = f32[128]{0} reduce-scatter(%z), replica_groups={{0,1,2,3},{4,5,6,7}}\n"
    "%cp = u8[64]{0} collective-permute(%w), source_target_pairs={{0,1}}\n"
    "%ag-start = f32[32]{0} all-gather-start(%v), replica_groups=[4,4]<=[16]\n"
    "%ag-done = f32[32]{0} all-gather-done(%ag-start)\n"
    "%not-a-collective = f32[10]{0} add(%p, %q)\n"
)


def test_collective_stats_parsing():
    st = H.collective_stats(HLO)
    assert st.by_op["all-gather"]["count"] == 2  # incl. -start, excl. -done
    assert st.by_op["all-reduce"]["count"] == 2
    # tuple all-reduce sums both components
    tuple_bytes = 16384 * 4 + 16384 * 256 * 4
    assert st.by_op["all-reduce"]["result_bytes"] == 1024 * 2 + tuple_bytes
    # ring models
    ag = 8192 * 8 * 4
    assert abs(st.by_op["all-gather"]["link_bytes"] - (0.75 * ag + 0.75 * 32 * 4)) < 1
    rs = st.by_op["reduce-scatter"]
    assert rs["link_bytes"] == pytest.approx(128 * 4 * 4 * 3 / 4)  # N=4 groups-list
    assert st.by_op["collective-permute"]["link_bytes"] == 64


def test_collective_stats_empty_module():
    st = H.collective_stats("")
    assert st.by_op == {} and st.result_bytes == 0 and st.link_bytes == 0.0
    # a module with no collectives at all behaves the same
    st = H.collective_stats("%add.1 = f32[8]{0} add(%a, %b)\n")
    assert st.to_dict() == {"by_op": {}, "result_bytes": 0, "link_bytes": 0.0}


def test_collective_stats_unknown_dtype_skipped():
    # a dtype outside _DTYPE_BYTES contributes zero bytes but the op is
    # still counted (future float formats must not crash the parser)
    hlo = (
        "%ar = f4e2m1[4096]{0} all-reduce(%x), replica_groups=[2,8]<=[16]\n"
        "%mixed = (f4e2m1[64]{0}, f32[64]{0}) all-reduce(%a, %b), "
        "replica_groups=[2,8]<=[16]\n"
    )
    st = H.collective_stats(hlo)
    assert st.by_op["all-reduce"]["count"] == 2
    # only the known f32 component of the tuple is sized
    assert st.result_bytes == 64 * 4


def test_collective_stats_async_pair_counted_once():
    # the -start op carries the payload; its -done must add nothing, even
    # for tuple-typed results
    hlo = (
        "%s = (f32[256]{0}, f32[1024]{0}) all-gather-start(%v), "
        "replica_groups=[4,4]<=[16]\n"
        "%d = (f32[256]{0}, f32[1024]{0}) all-gather-done(%s)\n"
    )
    st = H.collective_stats(hlo)
    assert st.by_op["all-gather"]["count"] == 1
    assert st.result_bytes == (256 + 1024) * 4
    assert st.by_op["all-gather"]["link_bytes"] == pytest.approx(
        0.75 * (256 + 1024) * 4
    )


def test_roofline_terms_dominance():
    t = H.roofline_terms(197e12, 0.0, 0.0)  # exactly 1s of compute
    assert t["dominant"] == "compute" and t["roofline_fraction"] == 1.0
    t = H.roofline_terms(197e12, 819e9 * 10, 0.0)
    assert t["dominant"] == "memory"
    assert t["roofline_fraction"] == pytest.approx(0.1)


def test_model_flops_conventions():
    cfg = get_config("granite_8b")
    train = H.model_flops(cfg, shape_case("train_4k"))
    decode = H.model_flops(cfg, shape_case("decode_32k"))
    n = cfg.param_count()
    assert train == 6.0 * n * 4096 * 256
    assert decode == 2.0 * n * 128


def test_long_500k_skip_rules():
    runnable = {}
    for arch in ("granite_8b", "mixtral_8x7b", "zamba2_1_2b", "rwkv6_3b",
                 "phi3_medium_14b", "whisper_base"):
        ok, _ = cell_is_runnable(get_config(arch), shape_case("long_500k"))
        runnable[arch] = ok
    assert runnable == {
        "granite_8b": False,  # full quadratic attention
        "mixtral_8x7b": True,  # SWA bounds the window
        "zamba2_1_2b": True,  # SSM state O(1)
        "rwkv6_3b": True,
        "phi3_medium_14b": False,
        "whisper_base": False,
    }


def test_production_mesh_contract():
    # shapes/axes exactly as the assignment specifies (no jax init needed
    # beyond the default single device: only validate the declared shape)
    import inspect

    from repro.launch import mesh

    src = inspect.getsource(mesh.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src and '("data", "model")' in src
