"""Family-polymorphic table pipeline: the TL1 activation-side family.

Unit tests cover the ternary quantizer (idempotence), the base-3 pair
packing round trip, the exact-mode oracle against a ternarized dense
matmul, and the Pallas kernels (plain + grouped, both activation modes,
non-multiple shapes) against the core oracle.

Pipeline tests cover family-tagged plan JSON (with the weight-family
default for pre-family payloads), the knapsack assigning DIFFERENT
families to different layers under one global byte budget, and the
satellite property: ``ModelPlan.total_lut_bytes`` equals the bytes of the
actually-converted table leaves across mixed weight/TL1 plans including
scan-stacked and expert trees.

Slow tests are the acceptance bar: a tiny LM planned entirely into TL1
(exact activation mode) produces greedy token streams identical to the
same model with ternarized dense weights — through ``generate`` AND the
``BatchingEngine`` — and the jitted decode step's program contains no
``dot_general`` over weight-sized operands.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.audit import multiplier_free_violations
from repro.configs.base import get_config
from repro.core.convert import LUTGroup, LUTLinear, convert_params
from repro.core.lut import LUTPlan
from repro.core.lut_tl1 import (
    TL1Plan,
    apply_tl1,
    build_tl1_tables,
    pack_ternary,
    quantize_acts,
    unpack_indices,
)
from repro.core.planner import ModelPlan, plan_from_json, plan_model, plan_to_json
from repro.core.quantize import (
    FixedPointFormat,
    ternary_fake_quant,
    ternary_quantize,
)
from repro.kernels.lut_affine.autotune import TunePoint
from repro.kernels.lut_tl1.ops import lut_tl1, lut_tl1_grouped
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve import (
    BatchingEngine,
    Request,
    generate,
    make_cache,
    make_decode_step,
)


# ---------------------------------------------------------------------------
# quantizer + packing
# ---------------------------------------------------------------------------


def test_ternary_quantize_idempotent():
    w = jax.random.normal(jax.random.PRNGKey(0), (37, 19)) * 0.3
    t, s = ternary_quantize(w)
    assert t.dtype == jnp.int8 and set(np.unique(np.asarray(t))) <= {-1, 0, 1}
    t2, s2 = ternary_quantize(s * t.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(t2))
    # the refit scale reproduces itself to fp32 rounding (the multiply
    # before the re-sum reassociates one ulp)
    np.testing.assert_allclose(float(s), float(s2), rtol=1e-6)


def test_pack_ternary_round_trip():
    rng = np.random.default_rng(1)
    for q, p in [(2, 3), (6, 5), (37, 19), (64, 8)]:
        t = rng.integers(-1, 2, size=(q, p)).astype(np.int8)
        packed = pack_ternary(jnp.asarray(t))
        kb = -(-(-(-q // 2)) // 2)  # ceil(ceil(q/2)/2)
        assert packed.shape == (kb, p) and packed.dtype == jnp.uint8
        idx = np.asarray(unpack_indices(packed))  # (2*kb, p) base-3 pairs
        tq = np.zeros((4 * kb, p), np.int8)
        tq[:q] = t  # zero-padded tail chunks
        want = (tq[0::2] + 1) * 3 + (tq[1::2] + 1)
        np.testing.assert_array_equal(idx, want)


def test_apply_tl1_exact_matches_ternary_dense():
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (37, 19)) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 1), (5, 37))
    b = jax.random.normal(jax.random.fold_in(key, 2), (19,)) * 0.01
    tables, s = build_tl1_tables(w)
    plan = TL1Plan(37, 19, act_bits=None)
    got = apply_tl1(tables, x, plan, bias=b, scale=s)
    want = x @ ternary_fake_quant(w) + b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_apply_tl1_int8_close():
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (64, 24)) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 64))
    tables, s = build_tl1_tables(w)
    got = np.asarray(apply_tl1(tables, x, TL1Plan(64, 24), scale=s))
    want = np.asarray(x @ ternary_fake_quant(w))
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.02  # int8 activation quantisation noise only


# ---------------------------------------------------------------------------
# kernels vs core oracle (interpret-mode Pallas, padding edges)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act_bits", [None, 8])
@pytest.mark.parametrize("shape", [(5, 38, 19), (8, 64, 128), (1, 2, 1)])
def test_lut_tl1_kernel_matches_oracle(act_bits, shape):
    B, q, p = shape
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (q, p)) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, q))
    b = jax.random.normal(jax.random.fold_in(key, 2), (p,)) * 0.01
    tables, s = build_tl1_tables(w)
    plan = TL1Plan(q, p, act_bits=act_bits)
    codes, act_scale = quantize_acts(x, plan)
    got = lut_tl1(codes, tables, act_scale, s, bias=b, interpret=True)
    want = apply_tl1(tables, x, plan, bias=b, scale=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("act_bits", [None, 8])
def test_lut_tl1_grouped_kernel_matches_member_dispatches(act_bits):
    G, B, q, p = 3, 4, 38, 19
    key = jax.random.PRNGKey(5)
    ws = jax.random.normal(key, (G, q, p)) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, q))
    built = [build_tl1_tables(ws[g]) for g in range(G)]
    tables = jnp.stack([t for t, _ in built])
    scale = jnp.stack([s for _, s in built])
    biases = jax.random.normal(jax.random.fold_in(key, 2), (G, p)) * 0.01
    plan = TL1Plan(q, p, act_bits=act_bits)
    codes, act_scale = quantize_acts(x, plan)
    got = lut_tl1_grouped(
        codes, tables, act_scale, scale, biases=biases, interpret=True
    )
    for g in range(G):
        want = lut_tl1(
            codes, tables[g], act_scale, scale[g], bias=biases[g], interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(got[g]), np.asarray(want), atol=1e-5
        )


def test_lut_tl1_leading_batch_dims():
    key = jax.random.PRNGKey(6)
    w = jax.random.normal(key, (30, 12)) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 3, 30))
    tables, s = build_tl1_tables(w)
    plan = TL1Plan(30, 12, act_bits=None)
    codes, act_scale = quantize_acts(x, plan)
    got = lut_tl1(codes, tables, act_scale, s, interpret=True)
    assert got.shape == (2, 3, 12)
    want = apply_tl1(tables, x, plan, scale=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# plan accounting, JSON round trips, family tagging
# ---------------------------------------------------------------------------


def test_tl1_plan_accounting():
    plan = TL1Plan(37, 19)
    assert plan.table_family == "tl1"
    assert plan.num_chunks == 19 and plan.packed_chunks == 10
    assert plan.total_lut_bytes == 10 * 19  # persistent packed bytes only
    assert plan.num_entries == 9 and plan.storage_bits == 8
    # per-step work: one 9-entry add-only LUT build per chunk + the gathers
    assert plan.shift_add_ops == 19 * (plan.num_chunks - 1) + 9 * plan.num_chunks


def test_plan_json_round_trip_both_families():
    fmt = FixedPointFormat(8, 6, signed=True)
    plans = [
        TL1Plan(64, 48),
        TL1Plan(64, 48, act_bits=None, blocks=(8, 128, 4)),
        LUTPlan(64, 48, 2, fmt, mode="bitplane"),
    ]
    for plan in plans:
        assert plan_from_json(plan_to_json(plan)) == plan
    # payloads serialized before the family axis existed stay loadable
    legacy = plan_to_json(plans[2])
    assert "family" not in legacy
    assert plan_from_json(legacy).table_family == "weight"
    with pytest.raises(ValueError):
        plan_from_json({"family": "nonsense", "in_features": 4, "out_features": 4})


def test_model_plan_families_property_and_json():
    fmt = FixedPointFormat(8, 6, signed=True)
    mp = ModelPlan(
        {"a": TL1Plan(8, 4), "b": LUTPlan(8, 4, 2, fmt, mode="bitplane")}
    )
    assert mp.families == ("weight", "tl1")
    again = ModelPlan.from_json(mp.to_json())
    assert again.layers == dict(mp.layers)
    assert "weight" in mp.summary() or "tl1" in mp.summary()


def test_tunepoint_json_family_default():
    pt = TunePoint.from_plan(TL1Plan(64, 48), batch=4)
    assert pt.family == "tl1" and pt.entries == 9 and pt.k == 16
    assert TunePoint.from_json(pt.to_json()) == pt
    legacy = {k: v for k, v in pt.to_json().items() if k != "family"}
    assert TunePoint.from_json(legacy).family == "weight"


# ---------------------------------------------------------------------------
# planner: family mixing under one byte budget (the tentpole's search axis)
# ---------------------------------------------------------------------------


def _three_layer_params():
    return {
        "a": {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 48))},
        "b": {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 48))},
        "c": {"w": jax.random.normal(jax.random.PRNGKey(2), (32, 24))},
    }


def test_plan_model_mixes_families_under_budget():
    """The knapsack assigns DIFFERENT table families to different layers
    under one global budget: TL1 is the min-bytes floor, fixed-point
    full-mode weight tables the fewer-ops / more-bytes upgrades, and an
    intermediate budget buys the upgrade only where it pays best."""
    params = _three_layer_params()
    fmt = FixedPointFormat(4, 3, signed=True)
    kw = dict(
        fmt=fmt, max_chunk=2, modes=("bitplane", "full"),
        families=("weight", "tl1"),
    )
    floor = plan_model(params, float("inf"), fmt=fmt, families=("tl1",))
    assert floor.families == ("tl1",)
    unbounded = plan_model(params, float("inf"), **kw)
    assert unbounded.families == ("weight",)  # full-mode wins on ops alone
    assert unbounded.total_lut_bytes > floor.total_lut_bytes

    mid = (floor.total_lut_bytes + unbounded.total_lut_bytes) // 3
    mp = plan_model(params, mid, **kw)
    assert mp.total_lut_bytes <= mid
    fams = {k: p.table_family for k, p in mp.layers.items()}
    assert set(fams.values()) == {"weight", "tl1"}, fams
    assert mp.families == ("weight", "tl1")
    # deterministic: same inputs, same plan
    assert plan_model(params, mid, **kw) == mp


def test_plan_model_rejects_unknown_family():
    with pytest.raises(ValueError, match="famil"):
        plan_model(_three_layer_params(), float("inf"), families=("lut3",))
    with pytest.raises(ValueError, match="famil"):
        plan_model(_three_layer_params(), float("inf"), families=())


# ---------------------------------------------------------------------------
# satellite: plan bytes == converted leaf bytes, mixed families, all layouts
# ---------------------------------------------------------------------------


def _table_leaf_bytes(tree) -> int:
    total = 0
    for node in jax.tree.leaves(
        tree, is_leaf=lambda n: isinstance(n, (LUTLinear, LUTGroup))
    ):
        if isinstance(node, (LUTLinear, LUTGroup)):
            total += node.tables.size * node.tables.dtype.itemsize
    return total


@pytest.mark.parametrize("families", [("tl1",), ("weight", "tl1")])
def test_plan_bytes_match_converted_leaves_mixed_trees(families):
    """``ModelPlan.total_lut_bytes`` equals the bytes of the table leaves
    conversion actually materialises — across mixed weight/TL1 plans, plain
    linears, scan stacks, grouped siblings, and stacked expert trees.
    (fp16 weight tables are the accounting width, TL1 leaves are uint8.)"""
    ks = jax.random.split(jax.random.PRNGKey(7), 8)
    E, d, f = 3, 32, 24
    params = {
        "fc": {"w": jax.random.normal(ks[0], (64, 48))},
        "scan": {"w": jax.random.normal(ks[1], (4, 64, 48))},
        "wk": {"w": jax.random.normal(ks[2], (64, 32))},
        "wv": {"w": jax.random.normal(ks[3], (64, 32))},
        "moe": {
            "router": jax.random.normal(ks[4], (d, E)),
            "w_gate": jax.random.normal(ks[5], (E, d, f)),
            "w_up": jax.random.normal(ks[6], (E, d, f)),
            "w_down": jax.random.normal(ks[7], (E, f, d)),
        },
    }
    fmt = FixedPointFormat(4, 3, signed=True)
    kw = dict(
        fmt=fmt, max_chunk=2, modes=("bitplane", "full"), families=families,
        convert_experts=True,
    )
    floor = plan_model(params, float("inf"), fmt=fmt, families=("tl1",),
                       convert_experts=True)
    if len(families) == 1:
        mp = floor
    else:
        unbounded = plan_model(params, float("inf"), **kw)
        mp = plan_model(
            params,
            (floor.total_lut_bytes + unbounded.total_lut_bytes) // 3,
            **kw,
        )
        assert len(mp.families) == 2  # the mixed case really mixes
    assert set(mp.copies.values()) >= {4, E} or families == ("tl1",)
    conv, report = convert_params(
        params, plan=mp, table_dtype=jnp.float16, convert_experts=True
    )
    leaf_bytes = _table_leaf_bytes(conv)
    assert leaf_bytes == mp.total_lut_bytes
    assert report.table_bytes == mp.total_lut_bytes


# ---------------------------------------------------------------------------
# acceptance: TL1-planned LM serves greedy streams identical to ternary dense
# ---------------------------------------------------------------------------

_PROMPTS = ((1, 2, 3, 4), (5, 6, 7), (9, 10, 11, 12, 13))


def _tl1_lm(seed=0):
    cfg = get_config("granite_8b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(seed))
    # exact activation mode: the TL1 path computes x @ (s*t) bit-for-bit up
    # to fp32 reassociation, so greedy streams must match the same model
    # with its planned weights ternarized in place
    mplan = plan_model(
        params, float("inf"), families=("tl1",), tl1_act_bits=None
    )
    assert mplan.families == ("tl1",) and mplan.groups
    tl1_params, report = convert_params(params, plan=mplan)
    assert report.grouped > 0
    tern = jax.tree.map(lambda a: a, params)  # fresh containers
    for key in mplan.layers:
        node = tern
        for part in key.split("/"):
            node = node[part]
        quant = ternary_fake_quant
        for _ in range(node["w"].ndim - 2):  # scan stacks: per-set scales
            quant = jax.vmap(quant)
        node["w"] = quant(node["w"])
    return cfg, params, tern, tl1_params, mplan


def _run_engine(params, ctx, max_new=4):
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32)
    reqs = [
        Request(uid=i, prompt=jnp.asarray(p, jnp.int32), max_new=max_new)
        for i, p in enumerate(_PROMPTS)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.uid: r.generated for r in reqs}


@pytest.mark.slow
def test_generate_tl1_equals_ternary_dense_greedy():
    cfg, _, tern, tl1_params, _ = _tl1_lm()
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    tctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    tokens = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    want = generate(tern, ctx, tokens, max_new=4, max_len=32)
    got = generate(tl1_params, tctx, tokens, max_new=4, max_len=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
def test_engine_tl1_equals_ternary_dense_greedy():
    cfg, _, tern, tl1_params, _ = _tl1_lm(seed=1)
    dense = _run_engine(tern, Ctx(cfg, ex=ExecCfg(remat="none")))
    tl1 = _run_engine(
        tl1_params, Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    )
    assert dense == tl1


@pytest.mark.slow
def test_tl1_decode_step_jaxpr_is_multiplier_free():
    """The decode step over a TL1-converted tree lowers to a program whose
    only dot_generals are smaller than the smallest PLANNED weight — every
    planned projection executes as the pack/unpack + 9-entry gather path.
    (The tied LM head reads the raw embedding table and is outside the
    conversion scope, so vocab-dim operands are exempt.)"""
    cfg, _, _, tl1_params, mplan = _tl1_lm()
    ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    decode = make_decode_step(ctx)
    cache = make_cache(cfg, 1, 16, ctx)
    jaxpr = jax.make_jaxpr(decode)(tl1_params, cache, jnp.zeros((1, 1), jnp.int32))

    min_w = min(p.in_features * p.out_features for p in mplan.layers.values())
    vocab_pad = -(-cfg.vocab_size // cfg.vocab_pad_multiple) * cfg.vocab_pad_multiple
    offenders = multiplier_free_violations(
        jaxpr,
        min_operand_elems=min_w,
        # tied embedding head: not a planned linear
        exempt_dims=(cfg.vocab_size, vocab_pad),
    )
    assert not offenders, (
        f"decode_step still multiplies over weight-sized operands: "
        f"{offenders} (threshold {min_w} elems)"
    )


# ---------------------------------------------------------------------------
# layers-level: fused group dispatch == per-member (both exec paths)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [False, True])
def test_tl1_group_fused_equals_unfused(use_pallas):
    cfg = get_config("granite_8b", reduced=True)
    key = jax.random.PRNGKey(8)
    q, p = 64, 32
    params = {
        "wk": {"w": jax.random.normal(key, (q, p)) * 0.1},
        "wv": {"w": jax.random.normal(jax.random.fold_in(key, 1), (q, p)) * 0.1},
    }
    mplan = ModelPlan(
        {"wk": TL1Plan(q, p), "wv": TL1Plan(q, p)}, groups=(("wk", "wv"),)
    )
    conv, _ = convert_params(params, plan=mplan)
    assert isinstance(conv["wk+wv"], LUTGroup)
    from repro.models.layers import fused_linears

    x = jax.random.normal(jax.random.fold_in(key, 2), (3, q))
    fused = fused_linears(
        conv, ["wk", "wv"], x,
        Ctx(cfg, ex=ExecCfg(lut_grouped=True, use_pallas=use_pallas)),
    )
    unfused = fused_linears(
        conv, ["wk", "wv"], x,
        Ctx(cfg, ex=ExecCfg(lut_grouped=False, use_pallas=use_pallas)),
    )
    for a, b in zip(fused, unfused):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
