"""Trainer substrate: loss goes down, checkpoint/restart resumes bit-exactly,
failure replay works, preemption saves state, data is deterministic."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import lm_stream, prefetch
from repro.data.synthetic import LMStreamConfig, image_batch, lm_batch
from repro.dist import checkpoint as ckpt
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.train.trainer import TrainConfig, Trainer


def _tiny_setup(tmp, steps=8, arch="granite_8b"):
    cfg = get_config(arch, reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    tc = TrainConfig(
        peak_lr=1e-2, warmup_steps=2, total_steps=steps, checkpoint_every=4,
        out_dir=str(tmp), microbatches=1,
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    data = lm_stream(cfg.vocab_size, 16, 4, seed=1)
    return cfg, ctx, tc, params, data


def test_loss_decreases(tmp_path):
    cfg, ctx, tc, params, data = _tiny_setup(tmp_path, steps=30)
    log = Trainer(ctx, tc, params, data).run(30)
    first = np.mean([r["loss"] for r in log[:5]])
    last = np.mean([r["loss"] for r in log[-5:]])
    assert last < first - 0.1, (first, last)


def test_data_is_deterministic():
    c = LMStreamConfig(512, 16, 4, seed=3)
    a, b = lm_batch(c, 7), lm_batch(c, 7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c2 = lm_batch(c, 8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c2["tokens"]))
    i1, l1 = image_batch(8, 5, seed=2)
    i2, l2 = image_batch(8, 5, seed=2)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_prefetch_preserves_order():
    it = prefetch(iter(range(20)), size=4)
    assert list(it) == list(range(20))


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.int32), "c": jnp.float32(3.5)},
    }
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 5, tree)
    ckpt.save_checkpoint(d, 10, tree)
    assert ckpt.latest_step(d) == 10
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out = ckpt.restore_checkpoint(d, 10, like)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    # keep_last GC
    for s in (15, 20, 25):
        ckpt.save_checkpoint(d, s, tree, keep_last=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert steps == ["step_00000020", "step_00000025"]
    # no tmp litter
    assert not [x for x in os.listdir(d) if x.startswith(".tmp")]


def test_restart_resumes_from_checkpoint(tmp_path):
    cfg, ctx, tc, params, data = _tiny_setup(tmp_path, steps=8)
    t1 = Trainer(ctx, tc, params, data)
    t1.run(8)
    assert ckpt.latest_step(os.path.join(str(tmp_path), "checkpoints")) == 8
    # "crash" and restart from scratch objects; should resume at step 8
    params2 = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    data2 = lm_stream(cfg.vocab_size, 16, 4, seed=1, start_step=8)
    t2 = Trainer(ctx, tc, params2, data2)
    assert t2.start_step == 8
    # params restored == trained params (bit-exact restore)
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_triggers_restore_and_replay(tmp_path):
    cfg, ctx, tc, params, data = _tiny_setup(tmp_path, steps=8)

    boom = {"armed": True}

    class FlakyIter:
        def __init__(self, inner):
            self.inner = inner
            self.n = 0

        def __iter__(self):
            return self

        def __next__(self):
            self.n += 1
            b = next(self.inner)
            if self.n == 6 and boom["armed"]:
                boom["armed"] = False
                # poison one batch -> NaN loss -> step failure path
                return {k: v for k, v in b.items()} | {
                    "tokens": b["tokens"] * 0 - 1  # invalid ids -> NaN-free? use big
                }
            return b

    # a tokens tensor of -1 indexes embed[-1] (valid) — instead force failure
    # by monkeypatching the step fn after construction:
    t = Trainer(ctx, tc, params, lm_stream(cfg.vocab_size, 16, 4, seed=1))
    real_step = t.step_fn
    calls = {"n": 0}

    def flaky(p, o, b):
        calls["n"] += 1
        if calls["n"] == 6:
            raise RuntimeError("injected node failure")
        return real_step(p, o, b)

    t.step_fn = flaky
    log = t.run(8)
    assert log[-1]["step"] == 8  # completed despite the injected failure
    assert calls["n"] >= 9  # replayed steps after restore


def test_preemption_checkpoints_and_exits(tmp_path):
    cfg, ctx, tc, params, data = _tiny_setup(tmp_path, steps=100)
    t = Trainer(ctx, tc, params, data)
    orig = t.step_fn

    def step_then_preempt(p, o, b):
        out = orig(p, o, b)
        t.request_preemption()
        return out

    t.step_fn = step_then_preempt
    log = t.run(100)
    assert len(log) == 1  # exited at the first boundary
    assert ckpt.latest_step(os.path.join(str(tmp_path), "checkpoints")) == 1
