"""Cache-layer unit tests: overflow guards, the S == T fast-path gate,
ring-window wraparound slot uniqueness, and slot-targeted masked prefill
metadata matching the retired full-cache splice."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.layers import Ctx, ExecCfg
from repro.serve import (
    CacheOverflowError,
    advance_meta,
    update_kv_cache,
    update_mla_cache,
)

B, T, KV, HD = 3, 8, 2, 4


def _ctx(window=None):
    cfg = get_config("granite_8b", reduced=True)
    if window is not None:
        cfg = dataclasses.replace(cfg, sliding_window=window)
    return Ctx(cfg, ex=ExecCfg(remat="none"))


def _meta_cache(index=None, with_flag=True):
    cache = {
        "pos": jnp.zeros((B, T), jnp.int32),
        "valid": jnp.zeros((B, T), bool),
        "index": jnp.zeros((B,), jnp.int32) if index is None else jnp.asarray(index),
    }
    if with_flag:
        cache["overflow"] = jnp.zeros((B,), bool)
    return cache


def _kv(key=0, t=T):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return (
        jax.random.normal(k1, (B, t, KV, HD), jnp.float32),
        jax.random.normal(k2, (B, t, KV, HD), jnp.float32),
    )


def test_advance_meta_flags_overflow():
    """index + S > T must set the per-slot overflow flag instead of letting
    the all-zero one-hot rows drop the tokens silently."""
    S = 4
    cache = _meta_cache(index=[0, 6, 5])  # slots 1 (6+4>8) and 2 (5+4>8) overflow
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    new, meta = advance_meta(cache, positions, None)
    np.testing.assert_array_equal(np.asarray(new["overflow"]), [False, True, True])
    np.testing.assert_array_equal(np.asarray(meta.index), [0, 6, 5])
    np.testing.assert_array_equal(np.asarray(new["index"]), [4, 10, 9])


def test_advance_meta_masked_rows_do_not_advance():
    S = 6
    cache = _meta_cache(index=[0, 3, 0])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mask = jnp.asarray(
        [[True] * 4 + [False] * 2, [False] * 6, [True] * 6]
    )  # row 0: 4 real tokens; row 1: untouched mid-decode slot; row 2: full
    new, meta = advance_meta(cache, positions, None, token_mask=mask)
    np.testing.assert_array_equal(np.asarray(new["index"]), [4, 3, 6])
    np.testing.assert_array_equal(
        np.asarray(new["valid"]).sum(1), [4, 0, 6]
    )
    assert not bool(new["overflow"].any())


def test_debug_overflow_assert_env_gated():
    """REPRO_CACHE_CHECKS=1 arms the in-graph assert (subprocess: env vars
    are read at trace time and jax caches aggressively)."""
    code = (
        "import jax.numpy as jnp, jax\n"
        "from repro.serve import advance_meta, CacheOverflowError\n"
        "cache = {'pos': jnp.zeros((1, 4), jnp.int32),\n"
        "         'valid': jnp.zeros((1, 4), bool),\n"
        "         'index': jnp.asarray([3])}\n"
        "positions = jnp.arange(2, dtype=jnp.int32)[None]\n"
        "try:\n"
        "    new, _ = advance_meta(cache, positions, None)\n"
        "    jax.block_until_ready(new['pos'])\n"
        "except Exception as e:\n"
        "    assert 'overflow' in str(e).lower() or 'cache write past' in str(e), e\n"
        "    print('RAISED')\n"
        "else:\n"
        "    print('SILENT')\n"
    )
    env = dict(os.environ, REPRO_CACHE_CHECKS="1",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert "RAISED" in out.stdout, (out.stdout, out.stderr)


def _full_prefill(cache, k, v, positions, ctx):
    new_meta, meta = advance_meta(cache, positions, ctx.cfg.sliding_window)
    layer = {"k": cache["k"], "v": cache["v"], "_meta": meta}
    upd, *_ = update_kv_cache(layer, k, v, positions, ctx)
    return dict(new_meta, **upd)


def test_full_length_fastpath_gated_on_fresh_index():
    """S == T whole-buffer overwrite must only apply to fresh rows (index
    0); rows mid-decode keep their K/V instead of being clobbered from
    slot 0, and the overflow flag records the rejected writes."""
    ctx = _ctx()
    k0, v0 = _kv(0)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    cache = dict(
        _meta_cache(index=[0, 5, 0]), k=jnp.zeros_like(k0), v=jnp.zeros_like(v0)
    )
    out = _full_prefill(cache, k0, v0, positions, ctx)
    got_k = np.asarray(out["k"])
    np.testing.assert_allclose(got_k[0], np.asarray(k0)[0])  # fresh: overwritten
    np.testing.assert_allclose(got_k[2], np.asarray(k0)[2])
    np.testing.assert_allclose(got_k[1], 0.0)  # mid-decode: untouched
    np.testing.assert_array_equal(np.asarray(out["overflow"]), [False, True, False])
    # metadata consistency: the rejected row (0 < index < T would land a
    # PARTIAL in-range write the fast path can't express) must not have its
    # tail slots marked valid either — valid claims only written K/V
    valid = np.asarray(out["valid"])
    assert valid[0].all() and valid[2].all()
    assert not valid[1].any(), valid[1]


def test_mla_full_length_fastpath_gated_on_fresh_index():
    ctx = _ctx()
    c = jax.random.normal(jax.random.PRNGKey(1), (B, T, 6), jnp.float32)
    r = jax.random.normal(jax.random.PRNGKey(2), (B, T, 4), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    cache = _meta_cache(index=[0, 2, 0])
    new_meta, meta = advance_meta(cache, positions, None)
    layer = {"c_kv": jnp.zeros_like(c), "k_rope": jnp.zeros_like(r), "_meta": meta}
    upd, *_ = update_mla_cache(layer, c, r, positions, ctx)
    got = np.asarray(upd["c_kv"])
    np.testing.assert_allclose(got[0], np.asarray(c)[0])
    np.testing.assert_allclose(got[1], 0.0)
    np.testing.assert_array_equal(
        np.asarray(new_meta["overflow"]), [False, True, False]
    )
    assert not np.asarray(new_meta["valid"])[1].any()  # rejected as a unit


def test_ring_wraparound_slots_unique():
    """S > T windowed writes: the surviving last-T positions must land in
    T distinct slots (positions % T is a permutation) with pos metadata
    matching, for nonzero per-slot start offsets too."""
    window = T
    ctx = _ctx(window=window)
    S = T + 5
    start = jnp.asarray([0, 3, 11], jnp.int32)
    positions = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    cache = _meta_cache(index=start)
    new, meta = advance_meta(cache, positions, window)
    slots = np.asarray(meta.slots)
    assert slots.shape == (B, T)
    for b in range(B):
        assert sorted(slots[b]) == list(range(T)), slots[b]  # a permutation
        # pos holds exactly the last T absolute positions
        want = np.asarray(positions[b, -T:])
        np.testing.assert_array_equal(np.sort(np.asarray(new["pos"])[b]), np.sort(want))
    assert bool(new["valid"].all())
    # K/V writes at those slots are unique too: each new row lands intact
    k, v = _kv(3, t=S)
    layer = {
        "k": jnp.zeros((B, T, KV, HD)),
        "v": jnp.zeros((B, T, KV, HD)),
        "_meta": meta,
    }
    upd, *_ = update_kv_cache(layer, k, v, positions, ctx)
    for b in range(B):
        for s_idx in range(T):
            slot = slots[b, s_idx]
            np.testing.assert_allclose(
                np.asarray(upd["k"])[b, slot],
                np.asarray(k)[b, S - T + s_idx],
                rtol=1e-6,
            )


def test_slot_targeted_prefill_matches_splice():
    """Masked multi-slot prefill writes must reproduce what the retired
    _splice_cache produced: run a batch-1 prefill, splice it into slot 1 of
    a busy cache by hand, and compare against the masked batched write."""
    ctx = _ctx()
    S, plen, slot = 6, 4, 1
    k_new, v_new = _kv(5, t=S)
    # busy cache: slot 0 mid-decode with 3 tokens, slot 2 with 5
    busy_k, busy_v = _kv(6)
    occupancy = np.zeros((B, T), bool)
    occupancy[0, :3] = True
    occupancy[2, :5] = True
    cache = {
        "pos": jnp.asarray(np.where(occupancy, np.arange(T)[None], 0), jnp.int32),
        "valid": jnp.asarray(occupancy),
        "index": jnp.asarray([3, 0, 5], jnp.int32),
        "overflow": jnp.zeros((B,), bool),
        "k": busy_k,
        "v": busy_v,
    }
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mask = jnp.zeros((B, S), bool).at[slot, :plen].set(True)
    new_cache, meta = advance_meta(cache, positions, None, token_mask=mask)
    layer = {"k": cache["k"], "v": cache["v"], "_meta": meta}
    upd, *_ = update_kv_cache(layer, k_new, v_new, positions, ctx)
    got = dict(new_cache, **upd)

    # reference: batch-1 fresh prefill of the real tokens, spliced by hand
    sub = {
        "pos": jnp.zeros((1, T), jnp.int32),
        "valid": jnp.zeros((1, T), bool),
        "index": jnp.zeros((1,), jnp.int32),
        "k": jnp.zeros((1, T, KV, HD)),
        "v": jnp.zeros((1, T, KV, HD)),
    }
    sub_pos = jnp.arange(plen, dtype=jnp.int32)[None]
    sub_new, sub_meta = advance_meta(sub, sub_pos, None)
    sub_layer = {"k": sub["k"], "v": sub["v"], "_meta": sub_meta}
    sub_upd, *_ = update_kv_cache(
        sub_layer, k_new[slot : slot + 1, :plen], v_new[slot : slot + 1, :plen],
        sub_pos, ctx,
    )
    want = {key: np.asarray(val).copy() for key, val in dict(cache).items()}
    for key in ("pos", "valid", "index"):
        want[key][slot] = np.asarray(sub_new[key])[0]
    for key in ("k", "v"):
        # the splice zeroed the slot's unwritten tail; the masked write
        # leaves stale values there instead — invisible behind valid=False,
        # so only the valid-masked region is part of the contract
        want[key][slot, :plen] = np.asarray(sub_upd[key])[0, :plen]

    for key in ("pos", "valid", "index"):
        np.testing.assert_array_equal(np.asarray(got[key]), want[key], err_msg=key)
    valid = np.asarray(got["valid"])
    for key in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(got[key])[valid], want[key][valid], rtol=1e-6, err_msg=key
        )
    assert not bool(got["overflow"].any())


def test_generate_overflow_raises():
    """Regression (the headline bug): generate() with max_len < S + max_new
    used to silently drop the overflowing tokens; it must raise now."""
    from repro.models.model import model_specs
    from repro.models.params import init_params
    from repro.serve import generate

    ctx = _ctx()
    params = init_params(model_specs(ctx.cfg), jax.random.PRNGKey(0))
    prompts = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    with pytest.raises(CacheOverflowError):
        generate(params, ctx, prompts, max_new=8, max_len=10)
