"""Paged KV cache + copy-on-write prefix sharing.

Fast tests cover the pure pieces: page routing in ``advance_meta``
(including the unmapped-page overflow contract), the paged write/gather
pair against the dense one-hot reference, in-graph page copies, the
host-side allocator's refcount/registry/eviction bookkeeping, and the
``repro.serve`` public API surface.

Slow tests are the acceptance bar: paged ``generate`` and the paged
``BatchingEngine`` produce token streams identical to the dense rectangle
(dense AND grouped-LUT execution), a shared system prompt is prefilled
once across N admissions with refcounted pages freed on retire, allocated
pages track ``ceil(len/page_size)`` rather than ``max_len``, and the
capacity edges (EOS at the final page slot, prompt + max_new exactly at
capacity, SWA ring wraparound over reused pages) hold.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve import (
    BatchingEngine,
    CacheOverflowError,
    Request,
    advance_meta,
    cache_specs,
    generate,
)
from repro.serve._cache import _onehot_write, _paged_write, copy_pages, paged_view
from repro.serve._paging import PageAllocator

B, T, PS, KV, HD = 2, 16, 4, 2, 4
MP = T // PS


def _ctx(name="granite_8b", window=None):
    cfg = get_config(name, reduced=True)
    if window is not None:
        cfg = dataclasses.replace(cfg, sliding_window=window)
    return Ctx(cfg, ex=ExecCfg(remat="none"))


def _paged_meta_cache(table=None, index=None):
    if table is None:  # identity mapping: slot b group g -> page b*MP+g
        table = np.arange(B * MP, dtype=np.int32).reshape(B, MP)
    return {
        "pos": jnp.zeros((B, T), jnp.int32),
        "valid": jnp.zeros((B, T), bool),
        "index": jnp.zeros((B,), jnp.int32) if index is None else jnp.asarray(index),
        "overflow": jnp.zeros((B,), bool),
        "page_table": jnp.asarray(table, jnp.int32),
    }


# ---------------------------------------------------------------------------
# advance_meta page routing + overflow contract
# ---------------------------------------------------------------------------


def test_advance_meta_routes_pages():
    S = 6
    cache = _paged_meta_cache(index=[0, 5])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    new, w = advance_meta(cache, positions, None)
    assert w.page_ids is not None and w.page_offsets is not None
    slots = np.asarray(w.slots)
    np.testing.assert_array_equal(
        np.asarray(w.page_offsets), slots % PS
    )
    table = np.arange(B * MP).reshape(B, MP)
    want_pid = np.take_along_axis(table, slots // PS, axis=1)
    np.testing.assert_array_equal(np.asarray(w.page_ids), want_pid)
    assert not bool(new["overflow"].any())
    np.testing.assert_array_equal(np.asarray(new["index"]), [6, 11])


def test_advance_meta_unmapped_page_flags_overflow():
    """A write landing in an unmapped (-1) page must flag overflow and be
    excluded from the write mask AND pos/valid — never silently dropped
    with metadata claiming it."""
    table = np.arange(B * MP, dtype=np.int32).reshape(B, MP)
    table[1, 1] = -1  # slot 1's second page unmapped
    S = 6  # slot 1 writes slots 0..5 -> group 1 (slots 4, 5) is unmapped
    cache = _paged_meta_cache(table=table)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    new, w = advance_meta(cache, positions, None)
    np.testing.assert_array_equal(np.asarray(new["overflow"]), [False, True])
    pid = np.asarray(w.page_ids)
    assert (pid[1, 4:] == -1).all()  # dropped tokens route nowhere
    mask = np.asarray(w.mask)
    assert mask[0].all() and not mask[1, 4:].any()
    valid = np.asarray(new["valid"])
    assert valid[0, :S].all()
    assert valid[1, :4].all() and not valid[1, 4:].any()


def test_advance_meta_past_capacity_flags_overflow_paged():
    S = 4
    cache = _paged_meta_cache(index=[0, T - 2])  # slot 1: 14 + 4 > 16
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    new, w = advance_meta(cache, positions, None)
    np.testing.assert_array_equal(np.asarray(new["overflow"]), [False, True])
    assert (np.asarray(w.page_ids)[1, 2:] == -1).all()


# ---------------------------------------------------------------------------
# paged write / gather / copy primitives vs the dense reference
# ---------------------------------------------------------------------------


def test_paged_write_view_matches_dense_reference():
    rng = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    S = 5
    dense = jax.random.normal(k1, (B, T, KV, HD))
    new = jax.random.normal(k2, (B, S, KV, HD))
    # unique slots per row, straddling a page boundary in row 1
    slots = jnp.stack([jnp.arange(S) + 3 * b for b in range(B)])
    mask = jnp.asarray([[True] * S, [True, True, False, True, True]])
    want = _onehot_write(dense, new, slots, mask)

    table = jnp.arange(B * MP, dtype=jnp.int32).reshape(B, MP)
    paged = dense.reshape(B * MP, PS, KV, HD)  # identity layout
    pids = jnp.take_along_axis(table, slots // PS, axis=1)
    got_buf = _paged_write(paged, new, pids, slots % PS, mask)
    got = paged_view(got_buf, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_copy_pages_moves_and_ignores_sentinels():
    L = 3
    buf = jax.random.normal(jax.random.PRNGKey(1), (L, B * MP, PS, KV, HD))
    src = jnp.asarray([2, -1], jnp.int32)
    dst = jnp.asarray([5, -1], jnp.int32)
    out = np.asarray(copy_pages(buf, src, dst))
    ref = np.asarray(buf).copy()
    ref[:, 5] = ref[:, 2]
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_cache_specs_rejects_ragged_pages():
    cfg = get_config("granite_8b", reduced=True)
    with pytest.raises(ValueError, match="whole number of pages"):
        cache_specs(cfg, 2, 10, page_size=4)


# ---------------------------------------------------------------------------
# host-side allocator: refcounts, registry, COW planning, eviction
# ---------------------------------------------------------------------------


def test_allocator_admit_register_retire_refcounts():
    al = PageAllocator(num_pages=16, page_size=4, num_slots=4, pages_per_slot=4)
    prompt = np.arange(10, dtype=np.int32)  # 2 full pages + 2 tail tokens
    plan = al.admit(0, prompt)
    assert plan.start == 0 and plan.copy_src == -1
    assert al.pages_in_use == 3  # ceil(10/4)
    al.register(0, prompt)
    assert al.pages_in_use == 3  # registry pins the same physical pages

    # partial match: same 8-token prefix, divergent tail
    p2 = np.concatenate([prompt[:8], np.asarray([99, 98], np.int32)])
    plan2 = al.admit(1, p2)
    assert plan2.start == 8 and plan2.copy_src == -1
    assert al.pages_in_use == 4  # 2 shared + 1 old tail + 1 new tail

    # full-prompt match (prompt == exactly the 2 registered pages): the
    # final token must still be re-prefilled to seed decode, and it lands
    # INSIDE the shared second page -> COW duplicates it
    plan3 = al.admit(2, prompt[:8])
    assert plan3.start == 7  # plen - 1: only the seeding token re-prefills
    assert plan3.copy_src >= 0 and plan3.copy_dst >= 0
    assert plan3.copy_src != plan3.copy_dst
    assert al.pages_in_use == 5

    al.retire(1), al.retire(2)
    assert al.pages_in_use == 3  # registry + slot 0 keep the prefix alive
    al.retire(0)
    assert al.pages_in_use == 2  # only the registry pins remain
    al.release_prefixes()
    assert al.pages_in_use == 0


def test_allocator_eviction_then_exhaustion():
    al = PageAllocator(num_pages=4, page_size=4, num_slots=2, pages_per_slot=4)
    p = np.arange(8, dtype=np.int32)
    assert al.admit(0, p) is not None  # 2 pages
    al.register(0, p)
    al.retire(0)  # pages survive via registry pins
    assert al.pages_in_use == 2
    # a 4-page prompt forces eviction of the (now unreferenced) registry
    big = np.arange(100, 116, dtype=np.int32)
    assert al.admit(0, big) is not None
    assert al.pages_in_use == 4
    # pool is now fully referenced by an active slot: nothing to evict
    assert al.admit(1, np.arange(50, 54, dtype=np.int32)) is None
    al.retire(0)
    assert al.admit(1, np.arange(50, 54, dtype=np.int32)) is not None


def test_allocator_windowed_maps_full_ring():
    al = PageAllocator(
        num_pages=8, page_size=4, num_slots=2, pages_per_slot=2, share=False
    )
    plan = al.admit_windowed(0)
    assert plan.start == 0
    assert (al.table[0] >= 0).all()
    assert al.pages_in_use == 2
    assert not al.ensure_page(0, 37)  # ring: always mapped already
    al.retire(0)
    assert al.pages_in_use == 0


# ---------------------------------------------------------------------------
# public API surface
# ---------------------------------------------------------------------------


def test_serve_public_api_surface():
    import repro.serve as serve

    for name in (
        "BatchingEngine", "Request", "generate", "make_cache",
        "abstract_cache", "CacheOverflowError", "SampleCfg", "CacheWrite",
    ):
        assert hasattr(serve, name), name


def test_deep_module_paths_removed():
    # the one-release PEP 562 deprecation shims are gone: the deep paths
    # fail loudly instead of resolving silently to stale modules
    import importlib

    for name in ("repro.serve.cache", "repro.serve.engine"):
        with pytest.raises(ModuleNotFoundError):
            importlib.import_module(name)


# ---------------------------------------------------------------------------
# end-to-end equivalence + capacity edges (compile-heavy: slow lane)
# ---------------------------------------------------------------------------


def _setup(name="granite_8b", seed=0, window=None):
    ctx = _ctx(name, window=window)
    params = init_params(model_specs(ctx.cfg), jax.random.PRNGKey(seed))
    return ctx, params


_PROMPTS = ((1, 2, 3, 4), (5, 6, 7), (9, 10, 11, 12, 13))


def _run_engine(params, ctx, max_new=4, prompts=_PROMPTS, **kw):
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32, **kw)
    reqs = [
        Request(uid=i, prompt=jnp.asarray(p, jnp.int32), max_new=max_new)
        for i, p in enumerate(prompts)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.uid: r.generated for r in reqs}, eng


@pytest.mark.slow
def test_generate_paged_matches_dense_gqa_and_mla():
    for name, plen in (("granite_8b", 6), ("minicpm3_4b", 5)):
        ctx, params = _setup(name)
        prompts = jnp.asarray([list(range(1, plen + 1))], jnp.int32)
        want = generate(params, ctx, prompts, max_new=5, max_len=16)
        got = generate(params, ctx, prompts, max_new=5, max_len=16, page_size=4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want), err_msg=name)


@pytest.mark.slow
def test_generate_paged_swa_ring_wraparound_reuses_pages():
    """Sliding-window ring writes wrap around logical slots — and therefore
    around the same physical pages.  The paged ring must match the dense
    ring exactly through multiple wraparounds (window 8 = 2 pages,
    14 total positions)."""
    ctx, params = _setup("mixtral_8x7b", seed=2, window=8)
    prompts = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    want = generate(params, ctx, prompts, max_new=8, max_len=32)
    got = generate(params, ctx, prompts, max_new=8, max_len=32, page_size=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
def test_generate_paged_exactly_at_capacity():
    """prompt + max_new - 1 == max_len must complete without a spurious
    CacheOverflowError: the final sampled token never writes KV."""
    ctx, params = _setup()
    prompts = jnp.asarray([[1, 2, 3, 4, 5]], jnp.int32)
    want = generate(params, ctx, prompts, max_new=12, max_len=16)
    got = generate(params, ctx, prompts, max_new=12, max_len=16, page_size=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).shape == (1, 12)


@pytest.mark.slow
def test_engine_paged_matches_dense_engine():
    ctx, params = _setup()
    dense, _ = _run_engine(params, ctx)
    paged, eng = _run_engine(params, ctx, page_size=4)
    assert dense == paged
    # drained engine: only registry pins hold pages; releasing them empties
    # the pool (refcounted frees on retire)
    eng.alloc.release_prefixes()
    assert eng.alloc.pages_in_use == 0


@pytest.mark.slow
def test_engine_paged_matches_grouped_lut_engine():
    """Acceptance: identical greedy streams dense-rectangle vs paged for
    grouped-LUT execution too (same style as test_moe_lut)."""
    from repro.core.convert import convert_params

    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(3))
    lut, rep = convert_params(params, chunk_size=1, convert_experts=True)
    assert rep.grouped > 0
    gctx = dataclasses.replace(ctx, ex=dataclasses.replace(ctx.ex, lut_grouped=True))
    dense, _ = _run_engine(lut, gctx)
    paged, _ = _run_engine(lut, gctx, page_size=4)
    assert dense == paged


@pytest.mark.slow
def test_engine_prefix_sharing_prefills_once_and_frees():
    """A shared 8-token system prompt across N=3 admissions is prefilled
    ONCE: later admissions map its pages and prefill only their 2-token
    tails (counted via engine.prefill_tokens); the shared pages are
    refcounted and freed once the registry releases them."""
    ctx, params = _setup()
    sys_p = (3, 1, 4, 1, 5, 9, 2, 6)
    prompts = tuple(sys_p + (20 + i, 30 + i) for i in range(3))
    dense, d_eng = _run_engine(params, ctx, prompts=prompts, prefill_bucket=16)
    paged, p_eng = _run_engine(
        params, ctx, prompts=prompts, prefill_bucket=16, page_size=4
    )
    assert dense == paged
    # dense prefills every prompt in full; paged prefills the first in full
    # and only the divergent tails after
    assert d_eng.prefill_tokens == sum(len(p) for p in prompts)
    assert p_eng.prefill_tokens == len(prompts[0]) + 2 * (len(prompts) - 1)
    # retire released the tails; the registry still pins the shared prefix
    assert p_eng.alloc.pages_in_use == len(sys_p) // 4
    p_eng.alloc.release_prefixes()
    assert p_eng.alloc.pages_in_use == 0


@pytest.mark.slow
def test_engine_paged_allocates_proportional_to_length():
    """Short prompts must occupy ceil(len/page_size) pages each — not the
    max_len rectangle (the memory-footprint acceptance criterion)."""
    ctx, params = _setup()
    eng = BatchingEngine(params, ctx, num_slots=3, max_len=32, page_size=4)
    for i in range(3):
        eng.submit(
            Request(uid=i, prompt=jnp.asarray([7 + i, 8, 9], jnp.int32), max_new=3)
        )
    assert eng.step()  # admission + first decode (still within page 0)
    # 3 slots x 3-token prompts: one page each; a dense rectangle would pin
    # the full 3 * (32/4) = 24 pages
    assert eng.alloc.pages_in_use == 3
    assert eng.alloc.pages_in_use < 3 * (32 // 4)
    while eng.step():
        pass
    eng.alloc.release_prefixes()
    assert eng.alloc.pages_in_use == 0


@pytest.mark.slow
def test_engine_eos_or_budget_at_final_page_slot():
    """A stream ending exactly at the last slot of a page: prompt 4 tokens
    (page 0 full), 5 generated — the final decode write lands at slot 7,
    the last slot of page 1.  No overflow, no dangling page, identical to
    dense."""
    ctx, params = _setup(seed=4)
    prompts = ((1, 2, 3, 4),)
    dense, _ = _run_engine(params, ctx, max_new=5, prompts=prompts)
    paged, eng = _run_engine(params, ctx, max_new=5, prompts=prompts, page_size=4)
    assert dense == paged
    assert len(paged[0]) == 5
    # the budget-exhaustion done fired on the write into slot 7 (page 1's
    # final slot); retire freed both pages, registry pins only page 0
    eng.alloc.release_prefixes()
    assert eng.alloc.pages_in_use == 0
    # EOS variant: stop at the token whose KV write lands page-final
    stream = dense[0]
    eos = int(stream[4])
    if eos in stream[:4]:  # greedy repeat would fire EOS before the edge
        pytest.skip("greedy stream repeats the boundary token")
    d2, _ = _run_engine(params, ctx, max_new=8, prompts=prompts, eos_id=eos)
    p2, eng2 = _run_engine(
        params, ctx, max_new=8, prompts=prompts, eos_id=eos, page_size=4
    )
    assert d2 == p2
    assert p2[0][-1] == eos and len(p2[0]) == 5
    eng2.alloc.release_prefixes()
    assert eng2.alloc.pages_in_use == 0
