"""Subprocess worker for distribution tests: runs under 16 fake CPU devices.

Usage: python tests/dist_worker.py <mode>
Prints one JSON line with results; exit code 0 on success.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import json
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import AxisType, make_mesh, shard_map


def small_mesh():
    return make_mesh((2, 2, 4), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3)


def mode_train_step_executes():
    """Sharded end-to-end train step on a 2x2x4 mesh matches 1-device run."""
    from repro.configs.base import get_config
    from repro.data.synthetic import LMStreamConfig, lm_batch
    from repro.dist.sharding import ShardCtx
    from repro.models.layers import Ctx, ExecCfg
    from repro.models.model import model_specs
    from repro.models.params import abstract_params, init_params
    from repro.train.trainer import TrainConfig, make_train_step

    cfg = get_config("qwen2_moe_a2_7b", reduced=True)  # exercises shard_map MoE
    mesh = small_mesh()
    ctx_d = Ctx(cfg, shard=ShardCtx(mesh), ex=ExecCfg(remat="none"))
    ctx_1 = Ctx(cfg, ex=ExecCfg(remat="none"))
    tc = TrainConfig(microbatches=1, compute_dtype=jnp.float32)

    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    batch = lm_batch(LMStreamConfig(cfg.vocab_size, 16, 8, seed=0), 0)
    from repro.optim.adamw import init_opt_state

    opt = init_opt_state(params)

    # distribute params per sharding rules
    sharded_params = jax.tree.map(lambda a: a, params)
    abs_p = abstract_params(
        model_specs(cfg), default_dtype=jnp.float32,
        sharding_fn=ctx_d.shard.param_sharding,
    )
    sharded_params = jax.tree.map(
        lambda a, s: jax.device_put(a, s.sharding), params, abs_p
    )
    step_d = jax.jit(make_train_step(ctx_d, tc))
    step_1 = jax.jit(make_train_step(ctx_1, tc))
    p_d, o_d, m_d = step_d(sharded_params, init_opt_state(sharded_params), batch)
    p_1, o_1, m_1 = step_1(params, opt, batch)
    dl = abs(float(m_d["loss"]) - float(m_1["loss"]))
    # parameters after one step agree
    diffs = [
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p_d), jax.tree.leaves(p_1))
    ]
    return {"loss_diff": dl, "max_param_diff": max(diffs)}


def mode_compression():
    from repro.dist.compression import compressed_psum

    mesh = small_mesh()
    key = jax.random.PRNGKey(0)
    g_pods = jax.random.normal(key, (2, 64, 32))  # per-pod gradients

    def per_pod(g, err):
        out, new_err = compressed_psum({"w": g[0]}, {"w": err[0]}, "pod")
        return out, jax.tree.map(lambda e: e[None], new_err)

    out, new_err = shard_map(
        per_pod,
        mesh=mesh,
        in_specs=(P("pod"), P("pod")),
        out_specs=(P(), P("pod")),
        axis_names={"pod"},
    )(g_pods, jnp.zeros((2, 64, 32)))
    # expected: mean across pods within int8 quantisation error
    want = np.asarray(g_pods.mean(0))
    got = np.asarray(out["w"])
    scale = float(jnp.abs(g_pods).max()) / 127.0
    err_mag = float(np.abs(got - want).max())
    # error feedback: residual equals what quantisation dropped locally
    errs = np.asarray(new_err["w"])  # (2, 64, 32) per-pod residuals
    return {
        "reduce_err": err_mag,
        "quant_step": scale,
        "err_nonzero": float(np.abs(errs).max()),
        "err_bounded": float(np.abs(errs).max()) <= scale * 0.51,
    }


def mode_elastic_ckpt():
    from repro.dist import checkpoint as ckpt

    mesh = small_mesh()
    big = jax.device_put(
        jnp.arange(16 * 32, dtype=jnp.float32).reshape(16, 32),
        NamedSharding(mesh, P(("pod", "data"), "model")),
    )
    tree = {"w": big}
    d = tempfile.mkdtemp()
    ckpt.save_checkpoint(d, 1, tree)
    # restore onto a DIFFERENT (smaller) mesh => elastic reshard
    mesh2 = make_mesh((2, 2), ("data", "model"),
                      axis_types=(AxisType.Auto,) * 2)
    like = {
        "w": jax.ShapeDtypeStruct(
            (16, 32), jnp.float32, sharding=NamedSharding(mesh2, P("data", "model"))
        )
    }
    out = ckpt.restore_checkpoint(d, 1, like)
    ok = bool(np.array_equal(np.asarray(jax.device_get(out["w"])),
                             np.asarray(jax.device_get(big))))
    n_shards = len(out["w"].sharding.device_set)
    return {"restored_equal": ok, "new_mesh_devices": n_shards}


def mode_compressed_train():
    """Train step with pod-compressed grads lowers and runs; grads close to
    uncompressed."""
    from repro.configs.base import get_config
    from repro.data.synthetic import LMStreamConfig, lm_batch
    from repro.dist.sharding import ShardCtx
    from repro.models.layers import Ctx, ExecCfg
    from repro.models.model import model_specs
    from repro.models.params import init_params
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config("granite_8b", reduced=True)
    mesh = small_mesh()
    ctx = Ctx(cfg, shard=ShardCtx(mesh), ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    batch = lm_batch(LMStreamConfig(cfg.vocab_size, 16, 8, seed=0), 0)

    tc_c = TrainConfig(microbatches=1, compute_dtype=jnp.float32,
                       compress_pod_grads=True)
    tc_p = TrainConfig(microbatches=1, compute_dtype=jnp.float32)
    pc, oc, mc = jax.jit(make_train_step(ctx, tc_c))(
        params, init_train_state(ctx, tc_c, params), batch
    )
    pp, op, mp = jax.jit(make_train_step(ctx, tc_p))(
        params, init_train_state(ctx, tc_p, params), batch
    )
    dl = abs(float(mc["loss"]) - float(mp["loss"]))
    gn = abs(float(mc["grad_norm"]) - float(mp["grad_norm"]))
    return {"loss_diff": dl, "gnorm_rel_diff": gn / (float(mp["grad_norm"]) + 1e-9)}


if __name__ == "__main__":
    mode = sys.argv[1]
    out = globals()[f"mode_{mode}"]()
    print("RESULT " + json.dumps(out))
