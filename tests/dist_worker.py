"""Subprocess worker for distribution tests: runs under 16 fake CPU devices.

Usage: python tests/dist_worker.py <mode>
Prints one JSON line with results; exit code 0 on success.
"""
# ruff: noqa: E402 -- the fake-device XLA_FLAGS must be set before jax imports
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import json
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import AxisType, make_mesh, shard_map


def small_mesh():
    return make_mesh((2, 2, 4), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3)


def mode_train_step_executes():
    """Sharded end-to-end train step on a 2x2x4 mesh matches 1-device run."""
    from repro.configs.base import get_config
    from repro.data.synthetic import LMStreamConfig, lm_batch
    from repro.dist.sharding import ShardCtx
    from repro.models.layers import Ctx, ExecCfg
    from repro.models.model import model_specs
    from repro.models.params import abstract_params, init_params
    from repro.train.trainer import TrainConfig, make_train_step

    cfg = get_config("qwen2_moe_a2_7b", reduced=True)  # exercises shard_map MoE
    mesh = small_mesh()
    ctx_d = Ctx(cfg, shard=ShardCtx(mesh), ex=ExecCfg(remat="none"))
    ctx_1 = Ctx(cfg, ex=ExecCfg(remat="none"))
    tc = TrainConfig(microbatches=1, compute_dtype=jnp.float32)

    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    batch = lm_batch(LMStreamConfig(cfg.vocab_size, 16, 8, seed=0), 0)
    from repro.optim.adamw import init_opt_state

    opt = init_opt_state(params)

    # distribute params per sharding rules
    sharded_params = jax.tree.map(lambda a: a, params)
    abs_p = abstract_params(
        model_specs(cfg), default_dtype=jnp.float32,
        sharding_fn=ctx_d.shard.param_sharding,
    )
    sharded_params = jax.tree.map(
        lambda a, s: jax.device_put(a, s.sharding), params, abs_p
    )
    step_d = jax.jit(make_train_step(ctx_d, tc))
    step_1 = jax.jit(make_train_step(ctx_1, tc))
    p_d, o_d, m_d = step_d(sharded_params, init_opt_state(sharded_params), batch)
    p_1, o_1, m_1 = step_1(params, opt, batch)
    dl = abs(float(m_d["loss"]) - float(m_1["loss"]))
    # parameters after one step agree
    diffs = [
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p_d), jax.tree.leaves(p_1))
    ]
    return {"loss_diff": dl, "max_param_diff": max(diffs)}


def mode_moe_mesh():
    """moe_ffn on the 2x2x4 mesh, dense AND LUT experts: evenly-divisible
    batches shard tokens over (pod, data) and the returned aux loss must be
    exactly the pmean of the shard-local aux losses (it is genuinely
    replicated, so the P() out-spec is sound); tiny decode batches
    ((B*S) % data_size != 0) drop data sharding and must reproduce the
    single-device output AND aux bit-closely."""
    from repro.configs.base import get_config
    from repro.core.convert import convert_params
    from repro.dist.sharding import ShardCtx
    from repro.models.layers import Ctx, ExecCfg
    from repro.models.moe import _route, moe_ffn, moe_specs
    from repro.models.params import init_params

    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    mesh = small_mesh()  # dp = pod x data = 4, tp = model = 4
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    lut, rep = convert_params(p, chunk_size=2, convert_experts=True)
    assert rep.grouped >= 1  # gate/up pre-stacked
    ctx1 = Ctx(cfg, ex=ExecCfg(remat="none"))
    ctxm = Ctx(cfg, shard=ShardCtx(mesh), ex=ExecCfg(remat="none"))

    key = jax.random.PRNGKey(1)
    x_even = jax.random.normal(key, (4, 8, cfg.d_model)) * 0.5  # 32 tok / 4 shards
    x_tiny = jax.random.normal(key, (1, 1, cfg.d_model)) * 0.5  # 1 % 4 != 0
    # the aux contract under data sharding: pmean of the per-shard locals
    shards = x_even.reshape(4, -1, cfg.d_model)  # (pod, data)-major row blocks
    aux_want = float(np.mean([float(_route(s, p["router"], cfg)[2]) for s in shards]))

    out = {}
    for name, prm in [("dense", p), ("lut", lut)]:
        y1, _ = moe_ffn(prm, x_even, ctx1)
        ym, am = moe_ffn(prm, x_even, ctxm)
        out[f"{name}_even_out_diff"] = float(jnp.abs(y1 - ym).max())
        out[f"{name}_even_aux_err"] = abs(float(am) - aux_want)
        y1t, a1t = moe_ffn(prm, x_tiny, ctx1)
        ymt, amt = moe_ffn(prm, x_tiny, ctxm)
        out[f"{name}_tiny_out_diff"] = float(jnp.abs(y1t - ymt).max())
        out[f"{name}_tiny_aux_diff"] = abs(float(a1t) - float(amt))
    return out


def mode_compression():
    from repro.dist.compression import compressed_psum

    mesh = small_mesh()
    key = jax.random.PRNGKey(0)
    g_pods = jax.random.normal(key, (2, 64, 32))  # per-pod gradients

    def per_pod(g, err):
        out, new_err = compressed_psum({"w": g[0]}, {"w": err[0]}, "pod")
        return out, jax.tree.map(lambda e: e[None], new_err)

    out, new_err = shard_map(
        per_pod,
        mesh=mesh,
        in_specs=(P("pod"), P("pod")),
        out_specs=(P(), P("pod")),
        axis_names={"pod"},
    )(g_pods, jnp.zeros((2, 64, 32)))
    # expected: mean across pods within int8 quantisation error
    want = np.asarray(g_pods.mean(0))
    got = np.asarray(out["w"])
    scale = float(jnp.abs(g_pods).max()) / 127.0
    err_mag = float(np.abs(got - want).max())
    # error feedback: residual equals what quantisation dropped locally
    errs = np.asarray(new_err["w"])  # (2, 64, 32) per-pod residuals
    return {
        "reduce_err": err_mag,
        "quant_step": scale,
        "err_nonzero": float(np.abs(errs).max()),
        "err_bounded": float(np.abs(errs).max()) <= scale * 0.51,
    }


def mode_elastic_ckpt():
    from repro.dist import checkpoint as ckpt

    mesh = small_mesh()
    big = jax.device_put(
        jnp.arange(16 * 32, dtype=jnp.float32).reshape(16, 32),
        NamedSharding(mesh, P(("pod", "data"), "model")),
    )
    tree = {"w": big}
    d = tempfile.mkdtemp()
    ckpt.save_checkpoint(d, 1, tree)
    # restore onto a DIFFERENT (smaller) mesh => elastic reshard
    mesh2 = make_mesh((2, 2), ("data", "model"),
                      axis_types=(AxisType.Auto,) * 2)
    like = {
        "w": jax.ShapeDtypeStruct(
            (16, 32), jnp.float32, sharding=NamedSharding(mesh2, P("data", "model"))
        )
    }
    out = ckpt.restore_checkpoint(d, 1, like)
    ok = bool(np.array_equal(np.asarray(jax.device_get(out["w"])),
                             np.asarray(jax.device_get(big))))
    n_shards = len(out["w"].sharding.device_set)
    return {"restored_equal": ok, "new_mesh_devices": n_shards}


def mode_compressed_train():
    """Train step with pod-compressed grads lowers and runs; grads close to
    uncompressed."""
    from repro.configs.base import get_config
    from repro.data.synthetic import LMStreamConfig, lm_batch
    from repro.dist.sharding import ShardCtx
    from repro.models.layers import Ctx, ExecCfg
    from repro.models.model import model_specs
    from repro.models.params import init_params
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config("granite_8b", reduced=True)
    mesh = small_mesh()
    ctx = Ctx(cfg, shard=ShardCtx(mesh), ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    batch = lm_batch(LMStreamConfig(cfg.vocab_size, 16, 8, seed=0), 0)

    tc_c = TrainConfig(microbatches=1, compute_dtype=jnp.float32,
                       compress_pod_grads=True)
    tc_p = TrainConfig(microbatches=1, compute_dtype=jnp.float32)
    pc, oc, mc = jax.jit(make_train_step(ctx, tc_c))(
        params, init_train_state(ctx, tc_c, params), batch
    )
    pp, op, mp = jax.jit(make_train_step(ctx, tc_p))(
        params, init_train_state(ctx, tc_p, params), batch
    )
    dl = abs(float(mc["loss"]) - float(mp["loss"]))
    gn = abs(float(mc["grad_norm"]) - float(mp["grad_norm"]))
    return {"loss_diff": dl, "gnorm_rel_diff": gn / (float(mp["grad_norm"]) + 1e-9)}


if __name__ == "__main__":
    mode = sys.argv[1]
    out = globals()[f"mode_{mode}"]()
    print("RESULT " + json.dumps(out))
