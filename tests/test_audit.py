"""The `repro.audit` invariant auditor.

Covers the shared walker (recursive descent through control-flow
sub-jaxprs, ``pallas_call`` opacity), the rule classes on clean audited
points, the seeded-violation regressions proving each rule actually
fires (a dense fallback spliced over a planned layer trips
multiplier-free; an un-prestacked group re-stacked per step trips
zero-copy; a ghost plan entry trips plan-consistency; an undonated cache
trips donation), and the manifest machinery behind ``python -m
repro.audit --check`` (census drift, loud failure on malformed or
missing baselines).
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.audit import (
    AUDIT_POINTS,
    ManifestError,
    audit_point,
    build_point,
    diff_manifests,
    donation_violations,
    iter_eqns,
    load_manifest,
    multiplier_free_violations,
    op_census,
    plan_consistency_violations,
    planned_weight_shapes,
    table_leaf_shapes,
    zero_copy_violations,
)
from repro.audit.__main__ import main as audit_main
from repro.core.convert import LUTGroup


@pytest.fixture(scope="module")
def granite_point():
    """Abstract artifacts for the attention weight-table point (no exec)."""
    return build_point(AUDIT_POINTS[0])


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------


def test_walker_descends_control_flow_sub_jaxprs():
    def f(x):
        def body(carry, _):
            return jax.lax.cond(
                carry.sum() > 0, lambda c: jnp.sin(c), lambda c: jnp.cos(c), carry
            ), None

        y, _ = jax.lax.scan(body, x, None, length=3)
        return jax.checkpoint(lambda z: jnp.tanh(z) * 2.0)(y)

    census = op_census(jax.make_jaxpr(f)(jnp.ones((4,))))
    # sin/cos live inside cond branches inside scan; tanh inside remat
    assert census["scan"] == 1
    assert census["sin"] >= 1 and census["cos"] >= 1
    assert census["tanh"] >= 1


def test_walker_surfaces_pallas_call_as_opaque_leaf():
    from repro.kernels.lut_affine.ops import lut_affine

    codes = jax.ShapeDtypeStruct((8, 2, 4), jnp.int32)
    tables = jax.ShapeDtypeStruct((4, 16, 128), jnp.float32)
    scales = jax.ShapeDtypeStruct((2,), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda c, t, s: lut_affine(c, t, s))(
        codes, tables, scales
    )
    walked = {id(eqn) for eqn in iter_eqns(jaxpr)}
    pallas = [e for e in iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]
    assert pallas, "kernel dispatch not surfaced"
    body = pallas[0].params["jaxpr"]
    body = getattr(body, "jaxpr", body)
    assert body.eqns, "kernel body unexpectedly empty"
    assert not any(id(e) in walked for e in body.eqns), (
        "walker descended into the opaque pallas_call body"
    )


# ---------------------------------------------------------------------------
# clean audited point: every rule holds on the real decode/prefill graphs
# ---------------------------------------------------------------------------


def test_audit_point_weight_family_is_clean(granite_point):
    entry = audit_point(AUDIT_POINTS[0], compile_hlo=False)
    assert all(not v for v in entry["rules"].values()), entry["rules"]
    assert entry["census"]["decode"]
    assert entry["plan"]["total_lut_bytes"] > 0
    # the range/overflow pass ran: the rule class is present (and clean),
    # and every planned layer carries a proved precision certificate
    assert "overflow" in entry["rules"]
    assert entry["precision"]
    for layer, cert in entry["precision"].items():
        assert cert["max_abs_acc"] > 0, layer
        assert cert["acc_dtype"] in ("int16", "int32", "float32"), layer
        assert cert["total_err"] >= 0, layer


# ---------------------------------------------------------------------------
# seeded violations: each rule class actually fires
# ---------------------------------------------------------------------------


def test_seeded_dense_fallback_trips_multiplier_free(granite_point):
    art = granite_point
    template, attn = art["template"], art["template"]["blocks"]["attn"]
    from repro.models.model import model_specs
    from repro.models.params import abstract_params

    raw = abstract_params(model_specs(art["cfg"]))
    broken = {
        **template,
        "blocks": {
            **template["blocks"],
            "attn": {
                k: v for k, v in attn.items() if k != "wq"
            } | {"wq": raw["blocks"]["attn"]["wq"]},
        },
    }
    jaxpr = jax.make_jaxpr(art["decode"])(
        broken, art["cache"], art["decode_tokens"]
    )
    hits = multiplier_free_violations(
        jaxpr, weight_shapes=planned_weight_shapes(art["mplan"])
    )
    assert hits and all(v.rule == "multiplier_free" for v in hits)
    assert any(v.primitive == "dot_general" for v in hits)
    # the clean template passes under the identical predicate
    clean = jax.make_jaxpr(art["decode"])(
        template, art["cache"], art["decode_tokens"]
    )
    assert not multiplier_free_violations(
        clean, weight_shapes=planned_weight_shapes(art["mplan"])
    )


def test_seeded_unprestacked_group_trips_zero_copy(granite_point):
    art = granite_point
    template = art["template"]
    group = template["blocks"]["attn"]["wk+wv"]
    assert isinstance(group, LUTGroup)
    g_axis = 1  # tables are (L, G, k, E, p)
    members = tuple(
        jax.ShapeDtypeStruct(
            group.tables.shape[:g_axis] + group.tables.shape[g_axis + 1 :],
            group.tables.dtype,
        )
        for _ in range(group.tables.shape[g_axis])
    )

    def restacking_decode(member_tables, params, cache, tokens):
        node = LUTGroup(
            tables=jnp.stack(member_tables, axis=g_axis),
            plan=group.plan,
            members=group.members,
            b=group.b,
            scale=group.scale,
        )
        spliced = {
            **params,
            "blocks": {
                **params["blocks"],
                "attn": {**params["blocks"]["attn"], "wk+wv": node},
            },
        }
        return art["decode"](spliced, cache, tokens)

    jaxpr = jax.make_jaxpr(restacking_decode)(
        members, template, art["cache"], art["decode_tokens"]
    )
    shapes = table_leaf_shapes(template)
    hits = zero_copy_violations(jaxpr, table_shapes=shapes)
    assert hits and all(v.rule == "zero_copy" for v in hits)
    assert any(v.primitive == "concatenate" for v in hits)
    # the stored pre-stacked layout passes under the identical predicate
    clean = jax.make_jaxpr(art["decode"])(
        template, art["cache"], art["decode_tokens"]
    )
    assert not zero_copy_violations(clean, table_shapes=shapes)


def test_seeded_ghost_plan_entry_trips_plan_consistency(granite_point):
    import dataclasses

    art = granite_point
    mplan = art["mplan"]
    assert not plan_consistency_violations(mplan, art["template"])
    some_plan = next(iter(mplan.layers.values()))
    ghost = dataclasses.replace(
        mplan, layers={**dict(mplan.layers), "ghost/linear": some_plan}
    )
    hits = plan_consistency_violations(ghost, art["template"])
    kinds = {v.primitive for v in hits}
    assert "never_consumed" in kinds  # the unconsumed plan entry
    assert "byte_mismatch" in kinds  # its bytes inflate total_lut_bytes


def test_seeded_undonated_cache_trips_donation(granite_point):
    art = granite_point
    n_params = len(jax.tree_util.tree_leaves(art["template"]))
    n_cache = len(jax.tree_util.tree_leaves(art["cache"]))
    cache_idx = range(n_params, n_params + n_cache)
    lowered_args = (art["template"], art["cache"], art["decode_tokens"])
    donated = (
        jax.jit(art["decode"], donate_argnums=(1,))
        .lower(*lowered_args)
        .compile()
        .as_text()
    )
    assert not donation_violations(donated, cache_idx)
    undonated = jax.jit(art["decode"]).lower(*lowered_args).compile().as_text()
    hits = donation_violations(undonated, cache_idx)
    assert hits and hits[0].primitive == "undonated_cache_leaf"


# ---------------------------------------------------------------------------
# manifest: drift detection + loud failure modes
# ---------------------------------------------------------------------------


def _fake_manifest(mul_count, acc=1024.0):
    return {
        "version": 2,
        "points": {
            "pt": {
                "rules": {},
                "census": {"decode": {"mul": mul_count, "add": 2}},
                "precision": {
                    "blocks/ffn": {"acc_dtype": "int32", "max_abs_acc": acc}
                },
            }
        },
    }


def test_diff_manifests_flags_census_drift_and_missing_points():
    base = _fake_manifest(3)
    assert diff_manifests(_fake_manifest(3), base) == []
    drift = diff_manifests(_fake_manifest(4), base)
    # one compact line per point/graph with signed per-primitive deltas
    assert len(drift) == 1
    assert "pt/decode: op census drift" in drift[0]
    assert "mul 3->4 (+1)" in drift[0]
    gone = diff_manifests({"version": 2, "points": {}}, base)
    assert gone and "missing from fresh" in gone[0]


def test_diff_manifests_flags_precision_drift():
    base = _fake_manifest(3)
    drift = diff_manifests(_fake_manifest(3, acc=2048.0), base)
    assert len(drift) == 1
    assert "precision drift at 'blocks/ffn'" in drift[0]
    assert "max_abs_acc 1024.0->2048.0" in drift[0]


def test_load_manifest_fails_loud_on_missing_and_malformed(tmp_path):
    with pytest.raises(ManifestError, match="not found"):
        load_manifest(str(tmp_path / "nope.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(ManifestError, match="not valid JSON"):
        load_manifest(str(bad))
    not_manifest = tmp_path / "rows.json"
    not_manifest.write_text(json.dumps([{"name": "x", "value": 1.0}]))
    with pytest.raises(ManifestError, match="malformed"):
        load_manifest(str(not_manifest))
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"version": 999, "points": {}}))
    with pytest.raises(ManifestError, match="version"):
        load_manifest(str(stale))


def test_cli_check_exits_2_before_tracing_on_missing_baseline(tmp_path):
    # exit code 2 (not 1): the baseline itself is unusable, and the CLI
    # must say so before paying for the fresh trace/compile
    rc = audit_main(["--check", "--baseline", str(tmp_path / "missing.json")])
    assert rc == 2


def test_cli_point_validates_names_and_rejects_write(capsys):
    # both are argparse errors: they fail before any (slow) tracing
    with pytest.raises(SystemExit) as e:
        audit_main(["--point", "no_such_point"])
    assert e.value.code == 2
    assert "unknown audit point" in capsys.readouterr().err
    with pytest.raises(SystemExit) as e:
        audit_main(["--write", "--point", "granite_weight"])
    assert e.value.code == 2
    assert "not valid with --write" in capsys.readouterr().err
