"""TableNet conversion pass: converted models must reproduce the
fp16-quantised-input reference, end to end, for the paper's models AND a
reduced LM from the zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.convert import convert_params, conversion_summary
from repro.core.lut import LUTPlan, build_luts
from repro.core.quantize import Float16Format
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_forward, model_specs
from repro.models.paper_models import PAPER_MODELS
from repro.models.params import init_params

pytestmark = pytest.mark.slow  # full conversion passes: ~97s on CPU


def _fp16_reference(forward, params, x, ctx):
    """Reference = same model with inputs to each linear pre-quantised to
    fp16 — emulated by running in fp16-quantising linear mode."""
    # The LUT path quantises the *input* of every converted linear to fp16;
    # emulate by monkey-wrapping is complex, so instead run full precision
    # and rely on tolerance: fp16 input quantisation error bounds the diff.
    return forward(params, x, ctx)


@pytest.mark.parametrize("name", ["linear", "mlp", "lenet"])
def test_paper_model_conversion_close(name):
    specs_fn, forward = PAPER_MODELS[name]
    params = init_params(specs_fn(), jax.random.PRNGKey(0))
    images = jax.random.uniform(jax.random.PRNGKey(1), (4, 28, 28))
    ctx = Ctx(get_config("granite_8b", reduced=True))  # cfg unused by paper models
    ref = forward(params, images, ctx)

    lut_params, report = convert_params(params, chunk_size=1)
    assert report.converted == {"linear": 1, "mlp": 3, "lenet": 4}[name]
    got = forward(lut_params, images, ctx)
    # inputs are ReLU outputs in ~[0, 30]: fp16 quantisation error ~1e-3 rel
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=5e-3, atol=5e-3
    )
    # classification must agree
    np.testing.assert_array_equal(
        np.argmax(np.asarray(got), -1), np.argmax(np.asarray(ref), -1)
    )


def test_conversion_is_exact_for_fp16_inputs():
    """When the input is already exactly fp16, LUT == matmul up to fp32
    summation order (the paper's exactness claim)."""
    specs_fn, forward = PAPER_MODELS["linear"]
    params = init_params(specs_fn(), jax.random.PRNGKey(2))
    ctx = Ctx(get_config("granite_8b", reduced=True))
    x = jax.random.uniform(jax.random.PRNGKey(3), (8, 28, 28))
    x = x.astype(jnp.float16).astype(jnp.float32)  # exactly representable
    ref = forward(params, x, ctx)
    lut_params, _ = convert_params(params, chunk_size=2)
    got = forward(lut_params, x, ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("chunk", [1, 2])
def test_reduced_lm_serves_via_lut(chunk):
    """A zoo LM converts and still produces sane (finite, argmax-stable)
    logits through the full forward."""
    cfg = get_config("granite_8b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_chunk=chunk))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(4))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, cfg.vocab_size)
    ref, _, _ = model_forward(params, {"tokens": tokens}, ctx)
    lut_params, report = convert_params(params, chunk_size=chunk)
    assert report.converted > 0
    got, _, _ = model_forward(lut_params, {"tokens": tokens}, ctx)
    assert bool(jnp.isfinite(got).all())
    # bf16 activations quantise losslessly to fp16? No — but closely; the
    # relative error budget through 2 layers stays small:
    ref_n, got_n = np.asarray(ref, np.float32), np.asarray(got, np.float32)
    denom = np.abs(ref_n).max() + 1e-6
    assert np.abs(got_n - ref_n).max() / denom < 0.05
    print(conversion_summary(report))


def test_expert_stack_conversion_builds_correct_tables():
    from repro.core.convert import LUTGroup, LUTLinear

    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(6))
    lut_params, report = convert_params(
        params, chunk_size=1, convert_experts=True
    )
    blk = jax.tree.map(lambda a: a[0], lut_params["blocks"])  # layer 0
    raw = jax.tree.map(lambda a: a[0], params["blocks"])["ffn"]
    # gate/up pre-stack into one LUTGroup: (E, G, k, entries, p) per layer
    group = blk["ffn"]["w_gate+w_up"]
    assert isinstance(group, LUTGroup)
    assert group.members == ("w_gate", "w_up")
    assert group.plan.chunk_size == 1 and group.plan.fmt.signed
    E, q, p = raw["w_gate"].shape
    plan = LUTPlan(q, p, 1, Float16Format(signed=True))
    for g, name in enumerate(group.members):
        want0 = build_luts(raw[name][0], plan)  # expert 0's tables
        np.testing.assert_allclose(
            np.asarray(group.tables[0, g]), np.asarray(want0),
            rtol=1e-6, atol=1e-6,
        )
    # the down projection stays a lone per-expert LUTLinear stack
    down = blk["ffn"]["w_down"]
    assert isinstance(down, LUTLinear)
    Ed, fd, dd = raw["w_down"].shape
    dplan = LUTPlan(fd, dd, 1, Float16Format(signed=True))
    want_down = build_luts(raw["w_down"][0], dplan)
    np.testing.assert_allclose(
        np.asarray(down.tables[0]), np.asarray(want_down), rtol=1e-6, atol=1e-6
    )
