"""repro.launch.backend: flag merging, env application, the post-init
guard, and the CLI argument trio."""
import argparse

import pytest

from repro.launch import backend
from repro.launch.backend import BackendConfig


def test_merged_flags_inherit_env_and_append_ours_last():
    cfg = BackendConfig(xla_flags=("--xla_b=2",))
    assert cfg.merged_xla_flags({"XLA_FLAGS": "--xla_a=1"}) == "--xla_a=1 --xla_b=2"
    assert cfg.merged_xla_flags({}) == "--xla_b=2"


def test_merged_flags_replace_stale_device_count():
    cfg = BackendConfig(host_device_count=512)
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8 --xla_a=1"}
    merged = cfg.merged_xla_flags(env)
    assert merged.count("--xla_force_host_platform_device_count") == 1
    assert "--xla_force_host_platform_device_count=512" in merged
    assert "--xla_a=1" in merged


def test_apply_writes_only_configured_keys(monkeypatch):
    monkeypatch.setattr(backend, "jax_initialised", lambda: False)
    env: dict[str, str] = {}
    BackendConfig().apply(env)
    assert env == {}  # empty config: no spurious empty XLA_FLAGS
    BackendConfig(platform="cpu", host_device_count=4).apply(env)
    assert env["JAX_PLATFORMS"] == "cpu"
    assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=4"


def test_apply_refuses_after_jax_initialised(monkeypatch):
    monkeypatch.setattr(backend, "jax_initialised", lambda: True)
    with pytest.raises(RuntimeError, match="already locked"):
        BackendConfig(platform="cpu").apply({})


def test_jax_initialised_reflects_backend_registry():
    # this test process imports jax and runs computations elsewhere in the
    # suite, so the only portable assertions are type and the sys.modules
    # coupling: a process that never imported jax reports False
    import sys

    assert isinstance(backend.jax_initialised(), bool)
    saved = {
        k: sys.modules.pop(k) for k in list(sys.modules) if k == "jax._src.xla_bridge"
    }
    try:
        assert backend.jax_initialised() is False
    finally:
        sys.modules.update(saved)


def test_cli_round_trip():
    ap = argparse.ArgumentParser()
    backend.add_args(ap)
    # values starting with "--" must use the = form, or argparse eats them
    argv = ["--platform", "cpu", "--host-device-count", "8"]
    argv += ["--xla-flag=--xla_a=1", "--xla-flag=--xla_b=2"]
    args = ap.parse_args(argv)
    cfg = backend.from_args(args)
    assert cfg == BackendConfig(
        platform="cpu", host_device_count=8, xla_flags=("--xla_a=1", "--xla_b=2")
    )
    assert backend.from_args(ap.parse_args([])) == BackendConfig()
