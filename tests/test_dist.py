"""Distribution tests: each case runs in a subprocess with 16 fake devices
(the parent process must keep its 1-device world for the other tests)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.dist, pytest.mark.slow]

_WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")


def _run(mode: str, timeout=900) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, _WORKER, mode],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"{mode} failed:\n{r.stderr[-3000:]}"
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_sharded_train_step_matches_single_device():
    out = _run("train_step_executes")
    assert out["loss_diff"] < 1e-4, out
    assert out["max_param_diff"] < 1e-4, out


def test_moe_mesh_tiny_decode_and_aux_pmean_dense_and_lut():
    """moe_ffn under shard_map, dense AND LUT experts: even batches match
    the single-device output with aux == pmean of the shard-local losses;
    tiny decode batches ((B*S) % data != 0) take the replication path and
    match single-device output and aux."""
    out = _run("moe_mesh")
    for name in ("dense", "lut"):
        assert out[f"{name}_even_out_diff"] < 1e-4, (name, out)
        assert out[f"{name}_even_aux_err"] < 1e-5, (name, out)
        assert out[f"{name}_tiny_out_diff"] < 1e-4, (name, out)
        assert out[f"{name}_tiny_aux_diff"] < 1e-5, (name, out)


def test_compressed_psum_correctness():
    out = _run("compression")
    # reduction error bounded by one quantisation step
    assert out["reduce_err"] <= out["quant_step"] * 1.01, out
    # residual is carried for error feedback and bounded by half a step
    assert out["err_nonzero"] > 0, out
    assert out["err_bounded"], out


def test_elastic_checkpoint_reshard():
    out = _run("elastic_ckpt")
    assert out["restored_equal"] is True
    assert out["new_mesh_devices"] == 4  # restored onto the smaller mesh


def test_compressed_train_step_close_to_uncompressed():
    out = _run("compressed_train")
    assert out["loss_diff"] < 1e-5, out  # loss is pre-update: identical-ish
    assert out["gnorm_rel_diff"] < 0.05, out  # int8 error stays small
