"""tools/bench_compare.py CLI contract: threshold/normalize comparison,
--require-ge with --ge-slack, --require-rows, and loud failures on
malformed input or silently vanished rows.  Driven through subprocess so
exit codes (the thing CI gates on) are what is actually asserted."""
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
SCRIPT = REPO / "tools" / "bench_compare.py"


def _rows(*pairs, unit="us"):
    return [{"name": n, "value": v, "unit": unit} for n, v in pairs]


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def _run(*args):
    return subprocess.run(
        [sys.executable, str(SCRIPT), *args],
        capture_output=True,
        text=True,
        cwd=REPO,
    )


# ---------------------------------------------------------------------------
# --baseline / --threshold / --normalize
# ---------------------------------------------------------------------------


def test_threshold_pass_and_fail(tmp_path):
    base = _write(tmp_path, "base.json", _rows(("kern/x_us", 100.0)))
    ok = _write(tmp_path, "ok.json", _rows(("kern/x_us", 120.0)))
    bad = _write(tmp_path, "bad.json", _rows(("kern/x_us", 200.0)))
    assert _run(ok, "--baseline", base, "--threshold", "1.5").returncode == 0
    r = _run(bad, "--baseline", base, "--threshold", "1.5")
    assert r.returncode == 1
    assert "regressed" in r.stdout


def test_normalize_divides_by_jnp_reference(tmp_path):
    # raw timing doubles, but so does the jnp normalizer row: the ratio of
    # ratios is 1.0 and the gate must pass under --normalize (and fail raw)
    tag = "B8_q4_p128_m1"
    base = _write(
        tmp_path,
        "base.json",
        _rows((f"kern/pallas_{tag}", 50.0), (f"kern/lut_affine_jnp_{tag}", 100.0)),
    )
    new = _write(
        tmp_path,
        "new.json",
        _rows((f"kern/pallas_{tag}", 100.0), (f"kern/lut_affine_jnp_{tag}", 200.0)),
    )
    assert _run(new, "--baseline", base, "--threshold", "1.5").returncode == 1
    assert (
        _run(new, "--baseline", base, "--threshold", "1.5", "--normalize").returncode
        == 0
    )


def test_missing_gated_baseline_row_fails(tmp_path):
    base = _write(tmp_path, "base.json", _rows(("kern/x_us", 100.0)))
    new = _write(tmp_path, "new.json", _rows(("kern/renamed_us", 100.0)))
    r = _run(new, "--baseline", base)
    assert r.returncode == 1
    assert "missing" in r.stdout


def test_matmul_ref_rows_are_context_only(tmp_path):
    # matmul_ref is dispatch-noise; a 10x swing must not gate, but with no
    # other comparable rows the "nothing compared" guard still fails the run
    base = _write(
        tmp_path,
        "base.json",
        _rows(("kern/matmul_ref_x_us", 10.0), ("kern/x_us", 100.0)),
    )
    new = _write(
        tmp_path,
        "new.json",
        _rows(("kern/matmul_ref_x_us", 100.0), ("kern/x_us", 100.0)),
    )
    assert _run(new, "--baseline", base).returncode == 0
    only = _write(tmp_path, "only.json", _rows(("kern/matmul_ref_x_us", 10.0)))
    r = _run(only, "--baseline", only)
    assert r.returncode == 1
    assert "no comparable rows" in r.stdout


# ---------------------------------------------------------------------------
# --require-ge / --ge-slack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "a,b,slack,rc",
    [
        (60.0, 100.0, 0.5, 0),  # 60 >= 50
        (40.0, 100.0, 0.5, 1),  # 40 <  50
        (95.0, 100.0, 0.9, 0),
        (85.0, 100.0, 0.9, 1),
    ],
)
def test_require_ge_slack(tmp_path, a, b, slack, rc):
    new = _write(
        tmp_path,
        "new.json",
        _rows(("serve/a", a), ("serve/b", b), unit="tok/s"),
    )
    r = _run(new, "--require-ge", "serve/a", "serve/b", "--ge-slack", str(slack))
    assert r.returncode == rc


def test_require_ge_missing_row_fails(tmp_path):
    new = _write(tmp_path, "new.json", _rows(("serve/a", 1.0), unit="tok/s"))
    r = _run(new, "--require-ge", "serve/a", "serve/absent")
    assert r.returncode == 1
    assert "missing row" in r.stdout


def test_require_ge_repeatable(tmp_path):
    new = _write(
        tmp_path,
        "new.json",
        _rows(("serve/a", 100.0), ("serve/b", 100.0), ("serve/c", 500.0), unit="t"),
    )
    ge = ["--require-ge", "serve/a", "serve/b", "--require-ge", "serve/c", "serve/a"]
    assert _run(new, *ge, "--ge-slack", "0.9").returncode == 0
    # one failing pair fails the run even when the other passes
    ge = ["--require-ge", "serve/a", "serve/b", "--require-ge", "serve/a", "serve/c"]
    assert _run(new, *ge, "--ge-slack", "0.9").returncode == 1


# ---------------------------------------------------------------------------
# --require-rows
# ---------------------------------------------------------------------------


def test_require_rows(tmp_path):
    companion = _write(
        tmp_path, "comp.json", _rows(("serve/a", 1.0), ("serve/b", 2.0), unit="t")
    )
    full = _write(
        tmp_path, "full.json", _rows(("serve/a", 5.0), ("serve/b", 6.0), unit="t")
    )
    partial = _write(tmp_path, "part.json", _rows(("serve/a", 5.0), unit="t"))
    assert _run(full, "--require-rows", companion).returncode == 0
    r = _run(partial, "--require-rows", companion)
    assert r.returncode == 1
    assert "serve/b" in r.stdout


# ---------------------------------------------------------------------------
# malformed input
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "payload",
    [
        {"not": "a list"},
        [{"name": "x"}],  # missing value
        [{"value": 1.0}],  # missing name
        ["just a string"],
    ],
)
def test_malformed_rows_rejected_at_load(tmp_path, payload):
    bad = _write(tmp_path, "bad.json", payload)
    r = _run(bad)
    assert r.returncode != 0
    assert "malformed" in r.stderr or "expected a JSON list" in r.stderr
