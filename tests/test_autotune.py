"""Autotuner contract: candidate legality, analytic determinism, ModelPlan
attachment, and the baseline write/check drift cycle CI runs."""
import dataclasses
import json

import jax
import pytest

from repro.configs.base import get_config
from repro.core.planner import ModelPlan, plan_model
from repro.kernels.lut_affine import autotune
from repro.kernels.lut_affine.autotune import (
    TunePoint,
    analytic_cost,
    attach_tuned_blocks,
    candidate_blocks,
    check_baseline,
    points_from_model_plan,
    search_blocks,
    write_baseline,
)
from repro.models.model import model_specs
from repro.models.params import init_params


@pytest.fixture(scope="module")
def mplan():
    cfg = get_config("granite_8b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    uniform = plan_model(params, float("inf"), max_chunk=2)
    return plan_model(
        params,
        uniform.total_lut_bytes // 2,
        max_chunk=2,
        modes=("bitplane", "bitplane_shift"),
        radices=(1, 2, 4),
        table_formats=(None, "i8"),
    )


# ---------------------------------------------------------------------------
# candidates + cost model
# ---------------------------------------------------------------------------


def test_candidates_are_legal():
    pt = TunePoint(B=4, k=96, entries=32, p=300, n=3, G=2, table_bytes=1)
    cands = candidate_blocks(pt)
    assert cands
    for bb, bp, bk in cands:
        assert bb % 8 == 0
        assert bp % 128 == 0
        assert bk & (bk - 1) == 0  # power of two
        assert bk <= pt.k
        # live table tile respects the kernel's VMEM budget, G-aware
        assert pt.G * bk * pt.entries * bp * pt.table_bytes <= autotune._VMEM_BUDGET


def test_candidates_exclude_vmem_busting_tiles():
    # 65536-entry fp32 tables: one (bp=128, bk=1) tile alone is 32 MiB
    pt = TunePoint(B=8, k=4, entries=65536, p=128, n=11, table_bytes=4)
    assert candidate_blocks(pt) == []
    assert search_blocks(pt) is None  # defer to the runtime heuristic


def test_search_is_deterministic_pure_function_of_point():
    pt = TunePoint(B=2, k=64, entries=32, p=64, n=3, table_bytes=1)
    winners = {search_blocks(pt, mode="analytic") for _ in range(5)}
    assert len(winners) == 1
    blk = winners.pop()
    assert blk in candidate_blocks(pt)
    # the winner really is the argmin of the analytic cost
    best = min(analytic_cost(pt, c) for c in candidate_blocks(pt))
    assert analytic_cost(pt, blk) == best


def test_unknown_mode_raises():
    pt = TunePoint(B=2, k=4, entries=8, p=16, n=2)
    with pytest.raises(ValueError, match="unknown autotune mode"):
        search_blocks(pt, mode="wallclock")


def test_point_json_round_trip():
    pt = TunePoint(B=2, k=64, entries=32, p=64, n=3, G=2, table_bytes=1)
    assert TunePoint.from_json(pt.to_json()) == pt


# ---------------------------------------------------------------------------
# ModelPlan attachment
# ---------------------------------------------------------------------------


def test_attach_tuned_blocks_sets_every_layer(mplan):
    tuned = attach_tuned_blocks(mplan, batch=2)
    assert set(tuned.layers) == set(mplan.layers)
    for key, plan in tuned.layers.items():
        assert plan.blocks is not None, key
        assert dataclasses.replace(plan, blocks=None) == dataclasses.replace(
            mplan.layers[key], blocks=None
        )
    # grouped members get identical plans after tuning, so groups still fuse
    for group in tuned.groups:
        plans = {tuned.layers[k] for k in group}
        assert len(plans) == 1


def test_tuned_plan_json_round_trip(mplan):
    tuned = attach_tuned_blocks(mplan, batch=2)
    back = ModelPlan.from_json(tuned.to_json())
    assert dict(back.layers) == dict(tuned.layers)
    key = next(iter(back.layers))
    assert isinstance(back.layers[key].blocks, tuple)


# ---------------------------------------------------------------------------
# baseline write / drift check (the CI cycle)
# ---------------------------------------------------------------------------


def test_write_then_check_baseline_round_trip(mplan, tmp_path):
    points = points_from_model_plan(mplan, batch=2)
    assert points  # dedup keeps at least one shape point
    path = str(tmp_path / "autotune.json")
    payload = write_baseline(path, points)
    assert payload["mode"] == "analytic"
    assert check_baseline(path) == []


def test_check_baseline_flags_drift(mplan, tmp_path):
    points = points_from_model_plan(mplan, batch=2)
    path = str(tmp_path / "autotune.json")
    write_baseline(path, points)
    with open(path) as f:
        payload = json.load(f)
    payload["points"][0]["blocks"] = [999, 999, 999]
    with open(path, "w") as f:
        json.dump(payload, f)
    errs = check_baseline(path)
    assert len(errs) == 1
    assert "999" in errs[0]


def test_cli_write_and_check(mplan, tmp_path):
    plan_path = str(tmp_path / "plan.json")
    with open(plan_path, "w") as f:
        json.dump(mplan.to_json(), f)
    base = str(tmp_path / "autotune.json")
    assert (
        autotune.main(
            ["write", "--baseline", base, "--plan", plan_path, "--batch", "2"]
        )
        == 0
    )
    assert autotune.main(["check", "--baseline", base]) == 0


@pytest.mark.slow  # converts + compiles decode twice: ~1 min on CPU
def test_tuned_plan_rides_checkpoint_and_streams_match_untuned(mplan, tmp_path):
    """plan -> checkpoint aux -> restore -> serve: the tuned plan survives
    byte-for-byte, and because blocks only retile the kernel, greedy token
    streams are identical to the same plan without blocks."""
    import numpy as np

    from repro.core.convert import convert_params
    from repro.dist.checkpoint import load_aux, save_checkpoint
    from repro.models.layers import Ctx, ExecCfg
    from repro.serve import generate

    cfg = get_config("granite_8b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    tuned = attach_tuned_blocks(mplan, batch=1)

    ckpt = str(tmp_path / "ckpt")
    save_checkpoint(ckpt, 1, params, aux={"model_plan": tuned.to_json()})
    restored = ModelPlan.from_json(load_aux(ckpt, 1)["model_plan"])
    assert dict(restored.layers) == dict(tuned.layers)

    untuned = dataclasses.replace(
        tuned,
        layers={
            k: dataclasses.replace(p, blocks=None) for k, p in tuned.layers.items()
        },
    )
    ex = ExecCfg(remat="none", use_pallas=True, lut_grouped=True)
    ctx = Ctx(cfg, ex=ex)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab_size)
    lut_t, rep_t = convert_params(params, plan=restored)
    lut_u, rep_u = convert_params(params, plan=untuned)
    assert rep_t.converted == rep_u.converted > 0
    got = generate(lut_t, ctx, tokens, max_new=3)
    want = generate(lut_u, ctx, tokens, max_new=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_committed_baseline_has_no_drift():
    """The baseline in the repo must match a fresh re-search (the CI step)."""
    import pathlib

    repo = pathlib.Path(__file__).resolve().parents[1]
    baseline = repo / "benchmarks" / "baselines" / "autotune.json"
    assert check_baseline(str(baseline)) == []
