"""Per-architecture smoke tests: reduced config, one forward + one train
gradient step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.launch.inputs import materialize
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_forward, model_specs
from repro.models.params import count_params, init_params
from repro.train.losses import cross_entropy

pytestmark = pytest.mark.slow  # compiles every family: ~75s on CPU

ARCHS = list_configs()


def _inputs(cfg, B=2, S=16, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    specs = {}
    if cfg.family == "vlm":
        n = cfg.num_image_tokens
        specs["embeds"] = jax.ShapeDtypeStruct((B, n, cfg.d_model), jnp.float32)
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - n), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif cfg.family == "encdec":
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return materialize(specs, key, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(1))
    batch = _inputs(cfg)
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache, aux = jax.jit(
        lambda p, i: model_forward(p, i, ctx)
    )(params, inputs)
    B = batch["tokens"].shape[0]
    S_total = 16
    assert logits.shape == (B, S_total, cfg.padded_vocab), logits.shape
    assert cache is None
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    assert bool(jnp.isfinite(aux)), "non-finite aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_gradient_step(arch):
    cfg = get_config(arch, reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="full"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(2))
    batch = _inputs(cfg, key=jax.random.PRNGKey(3))

    def loss_fn(p):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _, aux = model_forward(p, inputs, ctx)
        loss, _ = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        return loss + 0.001 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"loss={loss}"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), "non-finite grad"
    # at least 99% of parameter tensors receive gradient signal
    nonzero = sum(bool(jnp.any(g != 0)) for g in flat)
    assert nonzero >= int(0.9 * len(flat)), f"{nonzero}/{len(flat)} grads nonzero"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_full_config(arch):
    """Full (published) configs: parameter count lands in the advertised
    ballpark — catches mis-wired specs without allocating anything."""
    cfg = get_config(arch)
    n = count_params(model_specs(cfg))
    expected = {
        "qwen2_moe_a2_7b": (13e9, 15.5e9),  # 14.3B total (2.7B active)
        "mixtral_8x7b": (45e9, 48e9),
        "zamba2_1_2b": (1.0e9, 1.6e9),
        "minitron_4b": (3.7e9, 4.8e9),
        "granite_8b": (7.3e9, 8.6e9),
        "phi3_medium_14b": (13e9, 15e9),
        "minicpm3_4b": (3.6e9, 4.8e9),
        "llava_next_mistral_7b": (6.8e9, 7.8e9),
        "whisper_base": (55e6, 110e6),
        "rwkv6_3b": (2.7e9, 3.6e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B params"
