"""Chunked-parallel scan forms vs naive recurrent references (SSD + WKV),
plus chunked-vs-decode-step consistency. These are the numerics that make
zamba2/rwkv6 trainable and 500k-serveable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv import wkv_chunked, wkv_decode_step
from repro.models.ssm import ssd_chunked, ssd_decode_step


def ssd_recurrent_ref(x, dt, A, Bm, Cm):
    """O(L) recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T; y = C h."""
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    h = np.zeros((B_, H, P, N), np.float64)
    x, dt, A, Bm, Cm = (np.asarray(t, np.float64) for t in (x, dt, A, Bm, Cm))
    ys = []
    for t in range(L):
        dA = np.exp(dt[:, t] * A)  # (B, H)
        h = h * dA[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", x[:, t] * dt[:, t][..., None], Bm[:, t]
        )
        ys.append(np.einsum("bn,bhpn->bhp", Cm[:, t], h))
    return np.stack(ys, 1), h


def wkv_recurrent_ref(r, k, v, logw, u):
    """y_t = r_t (S_t + diag(u) k_t v_t^T); S_{t+1} = diag(w_t) S_t + k_t v_t^T."""
    B, L, H, K = r.shape
    V = v.shape[-1]
    S = np.zeros((B, H, K, V), np.float64)
    r, k, v, logw, u = (np.asarray(t, np.float64) for t in (r, k, v, logw, u))
    ys = []
    for t in range(L):
        kv = np.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
        ys.append(np.einsum("bhk,bhkv->bhv", r[:, t], S + u[None, :, :, None] * kv))
        S = S * np.exp(logw[:, t])[..., None] + kv
    return np.stack(ys, 1), S


@pytest.mark.parametrize("L,chunk", [(8, 4), (32, 8), (64, 64), (48, 16)])
def test_ssd_chunked_matches_recurrence(L, chunk):
    B, H, P, N = 2, 3, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(L), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, h_ref = ssd_recurrent_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), h_ref, rtol=2e-4, atol=2e-4)


def test_ssd_chunked_with_initial_state_and_decode():
    """prefill(L) then decode(1) == chunked over (L+1)."""
    B, L, H, P, N = 1, 16, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (B, L + 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L + 1, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L + 1, N))
    Cm = jax.random.normal(ks[4], (B, L + 1, N))
    y_all, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=1)
    _, state = ssd_chunked(x[:, :L], dt[:, :L], A, Bm[:, :L], Cm[:, :L], chunk=4)
    y1, _ = ssd_decode_step(
        x[:, L:], dt[:, L:], A, Bm[:, L:], Cm[:, L:], state
    )
    np.testing.assert_allclose(
        np.asarray(y1[:, 0]), np.asarray(y_all[:, L]), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("L,chunk", [(8, 4), (32, 8), (64, 32), (40, 8)])
def test_wkv_chunked_matches_recurrence(L, chunk):
    B, H, K, V = 2, 3, 8, 6
    ks = jax.random.split(jax.random.PRNGKey(L * 3), 5)
    r = jax.random.normal(ks[0], (B, L, H, K))
    k = jax.random.normal(ks[1], (B, L, H, K))
    v = jax.random.normal(ks[2], (B, L, H, V))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, L, H, K)))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    y, final = wkv_chunked(r, k, v, logw, u, chunk=chunk)
    y_ref, S_ref = wkv_recurrent_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), S_ref, rtol=2e-4, atol=2e-4)


def test_wkv_extreme_decay_no_overflow():
    """Strong decays must not overflow the chunked form (regression for the
    factored exp(-cum) formulation)."""
    B, L, H, K, V = 1, 64, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    r = jax.random.normal(ks[0], (B, L, H, K))
    k = jax.random.normal(ks[1], (B, L, H, K))
    v = jax.random.normal(ks[2], (B, L, H, V))
    logw = jnp.full((B, L, H, K), -50.0)  # near-total decay per step
    u = jnp.zeros((H, K))
    y, final = wkv_chunked(r, k, v, logw, u, chunk=32)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(final).all())
    y_ref, _ = wkv_recurrent_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)


def test_wkv_decode_continues_chunked():
    B, L, H, K, V = 2, 24, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    r = jax.random.normal(ks[0], (B, L + 1, H, K))
    k = jax.random.normal(ks[1], (B, L + 1, H, K))
    v = jax.random.normal(ks[2], (B, L + 1, H, V))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, L + 1, H, K)))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    y_all, _ = wkv_chunked(r, k, v, logw, u, chunk=1)
    _, S = wkv_chunked(r[:, :L], k[:, :L], v[:, :L], logw[:, :L], u, chunk=8)
    y1, _ = wkv_decode_step(r[:, L:], k[:, L:], v[:, L:], logw[:, L:], u, S)
    np.testing.assert_allclose(
        np.asarray(y1[:, 0]), np.asarray(y_all[:, L]), rtol=1e-4, atol=1e-4
    )
