"""plan_model() budget edge cases, determinism, byte accounting for
stacked scan/expert weights, and ModelPlan round-trips (JSON, checkpoint
aux, and checkpoint -> restore -> convert)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import get_config
from repro.core.convert import convert_params
from repro.core.lut import LUTPlan
from repro.core.planner import (
    ModelPlan,
    enumerate_plans,
    iter_linear_layers,
    plan_model,
    tradeoff_curve,
)
from repro.core.quantize import Float16Format
from repro.dist.checkpoint import load_aux, restore_checkpoint, save_checkpoint
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_forward, model_specs
from repro.models.params import init_params


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("granite_8b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Budget edge cases
# ---------------------------------------------------------------------------


def test_budget_below_minimal_footprint_raises(lm):
    _, params = lm
    with pytest.raises(ValueError, match="budget"):
        plan_model(params, 10)


def test_unbounded_budget_picks_fewest_ops_plan_per_layer(lm):
    _, params = lm
    mp = plan_model(params, float("inf"), max_chunk=2)
    fmt = Float16Format(signed=True)
    for key, (q, p), copies in iter_linear_layers(params):
        frontier = tradeoff_curve(
            enumerate_plans(q, p, fmt, modes=("bitplane",), max_chunk=2)
        )
        # fewest-ops point on the frontier is the last (largest) one
        assert mp.layers[key] == frontier[-1].plan, key
        assert mp.copies.get(key, 1) == copies, key
    # totals scale per table set actually built (scan-stacked layers: L)
    assert mp.total_shift_add_ops == sum(
        mp.copies.get(k, 1) * p.shift_add_ops for k, p in mp.layers.items()
    )
    assert any(v > 1 for v in mp.copies.values())  # blocks are scan-stacked


def test_partial_budget_mixes_chunk_sizes(lm):
    _, params = lm
    full = plan_model(params, float("inf"), max_chunk=2)
    half = plan_model(params, full.total_lut_bytes // 2, max_chunk=2)
    chunks = {p.chunk_size for p in half.layers.values()}
    assert chunks == {1, 2}, chunks  # greedy split the budget, not uniform
    assert half.total_lut_bytes <= full.total_lut_bytes // 2
    # spending less memory must cost ops, never save them
    assert half.total_shift_add_ops > full.total_shift_add_ops


def test_plan_model_is_deterministic(lm):
    _, params = lm
    budget = plan_model(params, float("inf"), max_chunk=2).total_lut_bytes // 2
    a = plan_model(params, budget, max_chunk=2)
    b = plan_model(params, budget, max_chunk=2)
    assert list(a.layers) == list(b.layers)
    assert a.layers == dict(b.layers)
    assert a.to_json() == b.to_json()


# ---------------------------------------------------------------------------
# Byte accounting for stacked scan / expert weights (the under-count fix)
# ---------------------------------------------------------------------------


def _expert_tree(L: int, E: int, d: int, f: int, seed: int) -> dict:
    """Minimal MoE-shaped tree: (L?, E, d, f) expert stacks + router."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    lead = (L, E) if L else (E,)
    return {
        "moe": {
            "router": jax.random.normal(ks[0], (d, E)),
            "w_gate": jax.random.normal(ks[1], lead + (d, f)),
            "w_up": jax.random.normal(ks[2], lead + (d, f)),
            "w_down": jax.random.normal(ks[3], lead + (f, d)),
        }
    }


def test_scan_stacked_bytes_respect_budget():
    """Regression: a (L, q, p) scan stack builds L table sets, so the
    planner must charge L x the per-set bytes — the pre-fix planner charged
    1x and a converted tree could exceed the budget by the scan depth."""
    L, q, p = 5, 12, 8
    params = {
        "stack": {"w": jax.random.normal(jax.random.PRNGKey(0), (L, q, p))},
        "fc": {"w": jax.random.normal(jax.random.PRNGKey(1), (q, p))},
    }
    full = plan_model(params, float("inf"), max_chunk=2)
    assert full.copies == {"stack": L}
    lo = plan_model(params, float("inf"), max_chunk=1).total_lut_bytes
    budget = (lo + full.total_lut_bytes) // 2
    mp = plan_model(params, budget, max_chunk=2)
    assert mp.total_lut_bytes <= budget
    # fp16 tables are the accounting width (out_bits=16): real bytes == plan
    lut, report = convert_params(params, plan=mp, table_dtype=jnp.float16)
    assert report.table_bytes == mp.total_lut_bytes
    assert report.table_bytes <= budget
    # the single (q, p) per-layer accounting would claim L+1 sets fit where
    # only the stacked charge reflects what conversion materialises
    per_set = sum(pl.total_lut_bytes for pl in mp.layers.values())
    assert report.table_bytes > per_set  # stacked charge really kicked in


def test_expert_bytes_respect_budget():
    """Regression: an expert-converted tree's table bytes stay within the
    planning budget (pre-fix: exceeded it by the expert count E)."""
    params = _expert_tree(L=0, E=6, d=10, f=8, seed=2)
    full = plan_model(params, float("inf"), max_chunk=2, convert_experts=True)
    assert full.copies["moe/w_gate"] == 6
    lo = plan_model(
        params, float("inf"), max_chunk=1, convert_experts=True
    ).total_lut_bytes
    budget = (lo + full.total_lut_bytes) // 2
    mp = plan_model(params, budget, max_chunk=2, convert_experts=True)
    lut, report = convert_params(
        params, plan=mp, convert_experts=True, table_dtype=jnp.float16
    )
    assert report.table_bytes == mp.total_lut_bytes
    assert report.table_bytes <= budget


@given(E=st.integers(2, 6), L=st.integers(0, 3), frac=st.floats(0.2, 0.95))
@settings(max_examples=8, deadline=None)
def test_budget_property_across_expert_counts_and_scan_depths(E, L, frac):
    """Acceptance property: for any expert count / scan depth / budget in
    the feasible range, plan_model(..., convert_experts=True) under budget
    B converts to a tree with report.table_bytes <= B."""
    params = _expert_tree(L, E, d=8, f=6, seed=E * 31 + L)
    kw = dict(max_chunk=2, convert_experts=True)
    lo = plan_model(params, float("inf"), max_chunk=1, convert_experts=True)
    hi = plan_model(params, float("inf"), **kw)
    budget = int(lo.total_lut_bytes + frac * (hi.total_lut_bytes - lo.total_lut_bytes))
    mp = plan_model(params, budget, **kw)
    assert mp.total_lut_bytes <= budget
    _, report = convert_params(
        params, plan=mp, convert_experts=True, table_dtype=jnp.float16
    )
    assert report.table_bytes == mp.total_lut_bytes
    assert report.table_bytes <= budget
    # copies survive the JSON round trip (budget math is restorable)
    back = ModelPlan.from_json(mp.to_json())
    assert back.copies == dict(mp.copies)
    assert back.total_lut_bytes == mp.total_lut_bytes


# ---------------------------------------------------------------------------
# Serialization round-trips
# ---------------------------------------------------------------------------


def test_model_plan_json_round_trip(lm):
    _, params = lm
    mp = plan_model(params, float("inf"), max_chunk=2)
    back = ModelPlan.from_json(mp.to_json())
    assert dict(back.layers) == dict(mp.layers)
    assert back.budget_bytes == mp.budget_bytes
    # LUTPlan fields survive exactly (frozen dataclass equality)
    key = next(iter(mp.layers))
    assert isinstance(back.layers[key], LUTPlan)


@pytest.mark.slow  # converts + compiles a reduced LM forward: ~30s
def test_plan_checkpoint_restore_convert_round_trip(lm, tmp_path):
    """ModelPlan -> checkpoint aux -> restore -> convert reproduces the
    conversion bit-for-bit, and the converted model matches the dense
    reference within the fp16-input tolerance."""
    cfg, params = lm
    full = plan_model(params, float("inf"), max_chunk=2)
    mp = plan_model(params, full.total_lut_bytes // 2, max_chunk=2)

    ckpt = str(tmp_path / "ckpt")
    save_checkpoint(ckpt, 7, params, aux={"model_plan": mp.to_json()})
    like = jax.tree.map(lambda a: a, params)
    restored = restore_checkpoint(ckpt, 7, like)
    mp_back = ModelPlan.from_json(load_aux(ckpt, 7)["model_plan"])
    assert dict(mp_back.layers) == dict(mp.layers)

    lut_a, rep_a = convert_params(params, plan=mp)
    lut_b, rep_b = convert_params(restored, plan=mp_back)
    assert rep_a == rep_b
    for a, b in zip(jax.tree.leaves(lut_a), jax.tree.leaves(lut_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the 0.5x-budget planned conversion passes the convert equivalence bar
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, cfg.vocab_size)
    ref, _, _ = model_forward(params, {"tokens": tokens}, ctx)
    got, _, _ = model_forward(lut_b, {"tokens": tokens}, ctx)
    ref_n, got_n = np.asarray(ref, np.float32), np.asarray(got, np.float32)
    denom = np.abs(ref_n).max() + 1e-6
    assert np.abs(got_n - ref_n).max() / denom < 0.05


def test_plan_mismatched_shape_raises(lm):
    _, params = lm
    mp = plan_model(params, float("inf"), max_chunk=1)
    key = next(iter(mp.layers))
    bad = dict(mp.layers)
    bad[key] = LUTPlan(3, 5, 1, Float16Format(signed=True))
    with pytest.raises(ValueError, match="plan for"):
        convert_params(params, plan=ModelPlan(layers=bad))
