"""Serving equivalence: prefill+decode must reproduce the full forward pass
for every cache family (GQA, SWA-ring, MLA, SSD, WKV, enc-dec cross), LUT
serving mode must work end-to-end, and the continuous batcher must match
one-shot generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.convert import convert_params
from repro.models.layers import Ctx, ExecCfg, SampleCfg
from repro.models.model import model_forward, model_specs
from repro.models.params import init_params
from repro.serve import (
    BatchingEngine,
    CacheOverflowError,
    Request,
    generate,
    make_cache,
    make_decode_step,
    make_prefill_step,
)

pytestmark = pytest.mark.slow  # prefill/decode compiles: ~79s on CPU

FAMS = [
    ("granite_8b", "gqa"),
    ("mixtral_8x7b", "swa+moe"),
    ("minicpm3_4b", "mla"),
    ("zamba2_1_2b", "ssd+shared-attn"),
    ("rwkv6_3b", "wkv"),
    ("whisper_base", "encdec"),
    ("qwen2_moe_a2_7b", "moe+shared-expert"),
    ("llava_next_mistral_7b", "vlm"),
]


def _setup(arch, B=2, S=12):
    cfg = get_config(arch, reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(3))
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extras = {}
    if cfg.family == "encdec":
        extras["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        extras["embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)
        ) * 0.1
    return cfg, ctx, params, tokens, extras


@pytest.mark.parametrize("arch,fam", FAMS)
def test_prefill_then_decode_matches_full_forward(arch, fam):
    cfg, ctx, params, tokens, extras = _setup(arch)
    B, S = tokens.shape
    n_pre = S - 4

    full_logits, _, _ = model_forward(params, {"tokens": tokens, **extras}, ctx)

    T = S + 8 if cfg.sliding_window is None else S + 8
    cache = make_cache(cfg, B, T, ctx, dtype=jnp.float32)
    prefill = make_prefill_step(ctx)
    decode = make_decode_step(ctx)
    logits_p, cache = prefill(
        params, {"tokens": tokens[:, :n_pre], **extras}, cache
    )
    got = [logits_p[:, -1]]
    for t in range(n_pre, S):
        _, logits_d, cache = decode(params, cache, tokens[:, t : t + 1])
        got.append(logits_d[:, -1])

    # VLM: image tokens shift logit positions by num_image_tokens
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    for i, t in enumerate(range(n_pre - 1, S)):
        if i == len(got) - 1:
            break
        want = np.asarray(full_logits[:, off + t], np.float32)
        have = np.asarray(got[i], np.float32)
        scale = np.abs(want).max() + 1e-6
        assert np.abs(have - want).max() / scale < 2e-3, (
            f"{arch} pos {t}: rel err {np.abs(have - want).max() / scale:.2e}"
        )


def test_swa_ring_cache_beyond_window():
    """Mixtral reduced (window=16): decoding past the window must still match
    the full forward (which masks beyond the window too)."""
    cfg, ctx, params, _, _ = _setup("mixtral_8x7b")
    B, S = 2, 24  # > window 16
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = model_forward(params, {"tokens": tokens}, ctx)
    cache = make_cache(cfg, B, S + 4, ctx, dtype=jnp.float32)
    prefill = make_prefill_step(ctx)
    decode = make_decode_step(ctx)
    _, cache = prefill(params, {"tokens": tokens[:, :20]}, cache)
    outs = []
    for t in range(20, S):
        _, lg, cache = decode(params, cache, tokens[:, t : t + 1])
        outs.append(lg[:, -1])
    for i, t in enumerate(range(20, S - 1)):
        want = np.asarray(full_logits[:, t + 1 - 1 + 1])  # logits at pos t (for t+1)
        want = np.asarray(full_logits[:, t])
        have = np.asarray(outs[i])
        scale = np.abs(want).max() + 1e-6
        assert np.abs(have - want).max() / scale < 2e-3


def test_lut_mode_generation_runs():
    """Converted (LUT) params generate tokens end to end; argmax agrees with
    the unconverted model for a short horizon."""
    cfg, ctx, params, tokens, _ = _setup("granite_8b", B=1, S=6)
    ref = generate(params, ctx, tokens, max_new=4)
    lut_params, report = convert_params(params, chunk_size=1)
    assert report.converted > 0
    got = generate(lut_params, ctx, tokens, max_new=4)
    assert got.shape == ref.shape
    # fp16 input quantisation may flip near-ties late; first tokens agree
    np.testing.assert_array_equal(np.asarray(got[:, 0]), np.asarray(ref[:, 0]))


def test_lut_grouped_decode_matches_ungrouped():
    """ExecCfg.lut_grouped fuses QKV / gate-up into one grouped dispatch;
    the generated tokens must be identical to the per-projection path."""
    cfg, ctx, params, tokens, _ = _setup("granite_8b", B=1, S=6)
    lut_params, report = convert_params(params, chunk_size=1)
    assert report.converted > 0
    ref = generate(lut_params, ctx, tokens, max_new=4)
    gctx = dataclasses.replace(
        ctx, ex=dataclasses.replace(ctx.ex, lut_grouped=True)
    )
    got = generate(lut_params, gctx, tokens, max_new=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_engine_exact_token_budget_and_prefill_finish():
    """Regression: a max_new=1 request must emit exactly one token (the
    prefill token) and never occupy a decode slot; a max_new=2 request
    runs exactly one decode step."""
    cfg, ctx, params, _, _ = _setup("granite_8b")
    prompts = [
        jnp.asarray([1, 2, 3], jnp.int32),
        jnp.asarray([4, 5], jnp.int32),
        jnp.asarray([6, 7, 8], jnp.int32),
        jnp.asarray([9, 10], jnp.int32),
    ]
    budgets = (1, 1, 2, 0)
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32)
    reqs = [
        Request(uid=i, prompt=p, max_new=n)
        for i, (p, n) in enumerate(zip(prompts, budgets))
    ]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
    assert steps == 1, steps  # only the max_new=2 request decodes, once
    for r, p, n in zip(reqs, prompts, budgets):
        assert r.done
        assert len(r.generated) == n, (r.uid, r.generated)
        if n:
            want = generate(params, ctx, p[None, :], max_new=n, max_len=32)
            assert r.generated == list(np.asarray(want[0])), r.uid


def test_engine_eos_at_prefill_frees_slot_immediately():
    cfg, ctx, params, _, _ = _setup("granite_8b")
    prompt = jnp.asarray([1, 2, 3, 4], jnp.int32)
    first = int(generate(params, ctx, prompt[None, :], max_new=1, max_len=32)[0, 0])
    eng = BatchingEngine(params, ctx, num_slots=1, max_len=32, eos_id=first)
    req = Request(uid=0, prompt=prompt, max_new=8)
    eng.submit(req)
    steps = 0
    while eng.step():
        steps += 1
    assert steps == 0  # EOS during prefill: the request never reaches decode
    assert req.done and req.generated == [first]


def test_batching_engine_matches_oneshot():
    cfg, ctx, params, _, _ = _setup("granite_8b")
    prompts = [
        jnp.asarray([1, 2, 3, 4], jnp.int32),
        jnp.asarray([5, 6, 7], jnp.int32),
        jnp.asarray([9, 10, 11, 12, 13], jnp.int32),
    ]
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, p in zip(reqs, prompts):
        want = generate(params, ctx, p[None, :], max_new=5, max_len=32)
        assert r.generated == list(np.asarray(want[0])), (
            r.uid, r.generated, list(np.asarray(want[0]))
        )


def _run_engine(params, ctx, prompts, max_new=5, **kw):
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32, **kw)
    reqs = [Request(uid=i, prompt=p, max_new=max_new) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, {r.uid: r.generated for r in reqs}


_PROMPTS = (
    (1, 2, 3, 4),
    (5, 6, 7),
    (9, 10, 11, 12, 13),
)


def _prompts():
    return [jnp.asarray(p, jnp.int32) for p in _PROMPTS]


def test_engine_batched_vs_per_slot_admit_identical_greedy():
    """Admission schedule must not change greedy token streams."""
    cfg, ctx, params, _, _ = _setup("granite_8b")
    _, batched = _run_engine(params, ctx, _prompts(), admit="batched")
    _, per_slot = _run_engine(params, ctx, _prompts(), admit="per-slot")
    assert batched == per_slot


def test_engine_ignores_logits_last_override():
    """The engine's batched prefill gathers each slot's logits at its own
    last real position, so it must force logits='all' internally — a Ctx
    built with ExecCfg(logits='last') (the dryrun prefill optimization)
    must not silently sample from pad-position logits."""
    cfg, ctx, params, _, _ = _setup("granite_8b")
    lctx = dataclasses.replace(
        ctx, ex=dataclasses.replace(ctx.ex, logits="last")
    )
    _, want = _run_engine(params, ctx, _prompts())
    _, got = _run_engine(params, lctx, _prompts())
    assert got == want


def test_engine_sampled_reproducible_across_schedules():
    """Sampled decode with a fixed PRNG key: token streams are a function
    of (seed, uid, position) only — identical across batched-admit and
    per-slot-admit schedules, and across reruns."""
    cfg, ctx, params, _, _ = _setup("granite_8b")
    scfg = SampleCfg(mode="temperature", temperature=0.7)
    _, a = _run_engine(params, ctx, _prompts(), sample=scfg, seed=7, admit="batched")
    _, b = _run_engine(params, ctx, _prompts(), sample=scfg, seed=7, admit="per-slot")
    _, c = _run_engine(params, ctx, _prompts(), sample=scfg, seed=7, admit="batched")
    assert a == b == c
    _, d = _run_engine(params, ctx, _prompts(), sample=scfg, seed=8, admit="batched")
    assert a != d  # a different seed actually changes the draws
    topk = SampleCfg(mode="top_k", temperature=0.7, top_k=3)
    _, e = _run_engine(params, ctx, _prompts(), sample=topk, seed=7, admit="batched")
    _, f = _run_engine(params, ctx, _prompts(), sample=topk, seed=7, admit="per-slot")
    assert e == f


def test_engine_lut_equals_dense_argmax():
    """Engine-level equivalence: grouped pre-stacked LUT serving and dense
    serving produce identical greedy token streams (the LUT fast path from
    PR 3 rides through the rebuilt scheduler unchanged)."""
    cfg, ctx, params, _, _ = _setup("granite_8b")
    lut_params, report = convert_params(params, chunk_size=1)
    assert report.converted > 0
    gctx = dataclasses.replace(
        ctx, ex=dataclasses.replace(ctx.ex, lut_grouped=True)
    )
    _, dense = _run_engine(params, ctx, _prompts(), max_new=4)
    _, lut = _run_engine(lut_params, gctx, _prompts(), max_new=4)
    assert dense == lut


def test_engine_single_readback_and_donation():
    """Steady-state decode: exactly ONE host readback per engine step, the
    donated cache buffers are consumed in place (zero full-cache copies),
    and the splice path is gone."""
    import repro.serve as engine_mod

    assert not hasattr(engine_mod, "_splice_cache")
    cfg, ctx, params, _, _ = _setup("granite_8b")
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32)
    for i, p in enumerate(_prompts()[:2]):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    assert eng.step()  # admission (1 prefill readback) + 1 decode readback
    assert eng.readbacks == 2
    old_k = eng.cache["layers"]["k"]
    old_pos = eng.cache["pos"]
    before = eng.readbacks
    assert eng.step()  # steady state: no admission
    assert eng.readbacks == before + 1
    # donation consumed the old cache in place — no full-cache allocation
    assert old_k.is_deleted()
    assert old_pos.is_deleted()


def test_engine_submit_overflow_raises():
    cfg, ctx, params, _, _ = _setup("granite_8b")
    eng = BatchingEngine(params, ctx, num_slots=1, max_len=8)
    with pytest.raises(CacheOverflowError):
        eng.submit(Request(uid=0, prompt=jnp.asarray([1, 2, 3, 4], jnp.int32),
                           max_new=6))


def test_generate_eos_matches_engine_semantics():
    """generate(eos_id=...) and BatchingEngine agree: the stream stops at
    the first EOS (inclusive); generate pads its rectangle with eos_id."""
    cfg, ctx, params, _, _ = _setup("granite_8b")
    prompt = jnp.asarray([1, 2, 3, 4], jnp.int32)
    free = list(np.asarray(
        generate(params, ctx, prompt[None, :], max_new=6, max_len=32)[0]
    ))
    eos = int(free[2])  # stop mid-stream
    got = list(np.asarray(
        generate(params, ctx, prompt[None, :], max_new=6, max_len=32, eos_id=eos)[0]
    ))
    stop = free.index(eos)
    assert got[: stop + 1] == free[: stop + 1]
    assert all(t == eos for t in got[stop + 1 :])  # post-EOS padding only
    eng, streams = _run_engine(params, ctx, [prompt], max_new=6, eos_id=eos)
    assert streams[0] == free[: stop + 1]  # engine truncates at EOS too
