"""Serving equivalence: prefill+decode must reproduce the full forward pass
for every cache family (GQA, SWA-ring, MLA, SSD, WKV, enc-dec cross), LUT
serving mode must work end-to-end, and the continuous batcher must match
one-shot generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.convert import convert_params
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_forward, model_specs
from repro.models.params import init_params
from repro.serve.engine import (
    BatchingEngine,
    Request,
    generate,
    make_cache,
    make_decode_step,
    make_prefill_step,
)

pytestmark = pytest.mark.slow  # prefill/decode compiles: ~79s on CPU

FAMS = [
    ("granite_8b", "gqa"),
    ("mixtral_8x7b", "swa+moe"),
    ("minicpm3_4b", "mla"),
    ("zamba2_1_2b", "ssd+shared-attn"),
    ("rwkv6_3b", "wkv"),
    ("whisper_base", "encdec"),
    ("qwen2_moe_a2_7b", "moe+shared-expert"),
    ("llava_next_mistral_7b", "vlm"),
]


def _setup(arch, B=2, S=12):
    cfg = get_config(arch, reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(3))
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extras = {}
    if cfg.family == "encdec":
        extras["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        extras["embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)
        ) * 0.1
    return cfg, ctx, params, tokens, extras


@pytest.mark.parametrize("arch,fam", FAMS)
def test_prefill_then_decode_matches_full_forward(arch, fam):
    cfg, ctx, params, tokens, extras = _setup(arch)
    B, S = tokens.shape
    n_pre = S - 4

    full_logits, _, _ = model_forward(params, {"tokens": tokens, **extras}, ctx)

    T = S + 8 if cfg.sliding_window is None else S + 8
    cache = make_cache(cfg, B, T, ctx, dtype=jnp.float32)
    prefill = make_prefill_step(ctx)
    decode = make_decode_step(ctx)
    logits_p, cache = prefill(
        params, {"tokens": tokens[:, :n_pre], **extras}, cache
    )
    got = [logits_p[:, -1]]
    for t in range(n_pre, S):
        _, logits_d, cache = decode(params, cache, tokens[:, t : t + 1])
        got.append(logits_d[:, -1])

    # VLM: image tokens shift logit positions by num_image_tokens
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    for i, t in enumerate(range(n_pre - 1, S)):
        if i == len(got) - 1:
            break
        want = np.asarray(full_logits[:, off + t], np.float32)
        have = np.asarray(got[i], np.float32)
        scale = np.abs(want).max() + 1e-6
        assert np.abs(have - want).max() / scale < 2e-3, (
            f"{arch} pos {t}: rel err {np.abs(have - want).max() / scale:.2e}"
        )


def test_swa_ring_cache_beyond_window():
    """Mixtral reduced (window=16): decoding past the window must still match
    the full forward (which masks beyond the window too)."""
    cfg, ctx, params, _, _ = _setup("mixtral_8x7b")
    B, S = 2, 24  # > window 16
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = model_forward(params, {"tokens": tokens}, ctx)
    cache = make_cache(cfg, B, S + 4, ctx, dtype=jnp.float32)
    prefill = make_prefill_step(ctx)
    decode = make_decode_step(ctx)
    _, cache = prefill(params, {"tokens": tokens[:, :20]}, cache)
    outs = []
    for t in range(20, S):
        _, lg, cache = decode(params, cache, tokens[:, t : t + 1])
        outs.append(lg[:, -1])
    for i, t in enumerate(range(20, S - 1)):
        want = np.asarray(full_logits[:, t + 1 - 1 + 1])  # logits at pos t (for t+1)
        want = np.asarray(full_logits[:, t])
        have = np.asarray(outs[i])
        scale = np.abs(want).max() + 1e-6
        assert np.abs(have - want).max() / scale < 2e-3


def test_lut_mode_generation_runs():
    """Converted (LUT) params generate tokens end to end; argmax agrees with
    the unconverted model for a short horizon."""
    cfg, ctx, params, tokens, _ = _setup("granite_8b", B=1, S=6)
    ref = generate(params, ctx, tokens, max_new=4)
    lut_params, report = convert_params(params, chunk_size=1)
    assert report.converted > 0
    got = generate(lut_params, ctx, tokens, max_new=4)
    assert got.shape == ref.shape
    # fp16 input quantisation may flip near-ties late; first tokens agree
    np.testing.assert_array_equal(np.asarray(got[:, 0]), np.asarray(ref[:, 0]))


def test_lut_grouped_decode_matches_ungrouped():
    """ExecCfg.lut_grouped fuses QKV / gate-up into one grouped dispatch;
    the generated tokens must be identical to the per-projection path."""
    cfg, ctx, params, tokens, _ = _setup("granite_8b", B=1, S=6)
    lut_params, report = convert_params(params, chunk_size=1)
    assert report.converted > 0
    ref = generate(lut_params, ctx, tokens, max_new=4)
    gctx = dataclasses.replace(
        ctx, ex=dataclasses.replace(ctx.ex, lut_grouped=True)
    )
    got = generate(lut_params, gctx, tokens, max_new=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_engine_exact_token_budget_and_prefill_finish():
    """Regression: a max_new=1 request must emit exactly one token (the
    prefill token) and never occupy a decode slot; a max_new=2 request
    runs exactly one decode step."""
    cfg, ctx, params, _, _ = _setup("granite_8b")
    prompts = [
        jnp.asarray([1, 2, 3], jnp.int32),
        jnp.asarray([4, 5], jnp.int32),
        jnp.asarray([6, 7, 8], jnp.int32),
        jnp.asarray([9, 10], jnp.int32),
    ]
    budgets = (1, 1, 2, 0)
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32)
    reqs = [
        Request(uid=i, prompt=p, max_new=n)
        for i, (p, n) in enumerate(zip(prompts, budgets))
    ]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
    assert steps == 1, steps  # only the max_new=2 request decodes, once
    for r, p, n in zip(reqs, prompts, budgets):
        assert r.done
        assert len(r.generated) == n, (r.uid, r.generated)
        if n:
            want = generate(params, ctx, p[None, :], max_new=n, max_len=32)
            assert r.generated == list(np.asarray(want[0])), r.uid


def test_engine_eos_at_prefill_frees_slot_immediately():
    cfg, ctx, params, _, _ = _setup("granite_8b")
    prompt = jnp.asarray([1, 2, 3, 4], jnp.int32)
    first = int(generate(params, ctx, prompt[None, :], max_new=1, max_len=32)[0, 0])
    eng = BatchingEngine(params, ctx, num_slots=1, max_len=32, eos_id=first)
    req = Request(uid=0, prompt=prompt, max_new=8)
    eng.submit(req)
    steps = 0
    while eng.step():
        steps += 1
    assert steps == 0  # EOS during prefill: the request never reaches decode
    assert req.done and req.generated == [first]


def test_batching_engine_matches_oneshot():
    cfg, ctx, params, _, _ = _setup("granite_8b")
    prompts = [
        jnp.asarray([1, 2, 3, 4], jnp.int32),
        jnp.asarray([5, 6, 7], jnp.int32),
        jnp.asarray([9, 10, 11, 12, 13], jnp.int32),
    ]
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, p in zip(reqs, prompts):
        want = generate(params, ctx, p[None, :], max_new=5, max_len=32)
        assert r.generated == list(np.asarray(want[0])), (
            r.uid, r.generated, list(np.asarray(want[0]))
        )
