"""Property tests for the TableNet core: the LUT path must compute exactly
the quantised affine map (the paper's central claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.lut import (
    LUTPlan,
    apply_luts,
    build_luts,
    lut_affine_reference,
    pack_codes,
    plane_scales,
    quantized_matmul_reference,
)
from repro.core.quantize import (
    FixedPointFormat,
    Float16Format,
    build_stochastic_rounding_lut,
    stochastic_round_via_lut,
)

pytestmark = pytest.mark.slow  # property sweeps over LUT plans: ~minutes on CPU


def _int_weights(key, q, p, wbits=4):
    """Integer-valued weights so fp32 accumulation is exact -> bitwise tests."""
    lo = -(2 ** (wbits - 1))
    return jax.random.randint(key, (q, p), lo, -lo).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Quantizer invariants
# ---------------------------------------------------------------------------


@given(
    bits=st.integers(2, 8),
    frac=st.integers(0, 8),
    signed=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_fixed_point_roundtrip_and_bitplanes(bits, frac, signed):
    fmt = FixedPointFormat(bits, frac, signed)
    codes = jnp.arange(fmt.code_min, fmt.code_max + 1, dtype=jnp.int32)
    vals = fmt.dequantize(codes)
    # quantize(dequantize(c)) == c for every representable code
    np.testing.assert_array_equal(fmt.quantize(vals), codes)
    # bitplane decomposition reconstructs the value exactly
    planes = fmt.bitplanes(codes)  # (n, N)
    scales = fmt.plane_scales()  # (n,)
    recon = np.einsum("n,nN->N", scales, np.asarray(planes))
    np.testing.assert_allclose(recon, np.asarray(vals), rtol=0, atol=0)


def test_fixed_point_saturates():
    fmt = FixedPointFormat(4, 2, signed=True)
    assert int(fmt.quantize(jnp.float32(100.0))) == fmt.code_max
    assert int(fmt.quantize(jnp.float32(-100.0))) == fmt.code_min


def test_float16_decompose_exact():
    f = Float16Format()
    # every class of value: zero, subnormals, normals, large
    x = jnp.asarray(
        [0.0, 5.96e-8, 6.0e-5, 6.1e-5, 0.5, 1.0, 1.5, 333.25, 65504.0], jnp.float32
    )
    h = f.quantize(x)
    exp, planes = f.decompose(h)
    sigma = f.sigma(exp)
    weights = 2.0 ** np.arange(f.num_planes)
    recon = np.einsum("n,nN->N", weights, np.asarray(planes)) * np.asarray(sigma)
    np.testing.assert_allclose(recon, np.asarray(h, np.float32), rtol=0, atol=0)


def test_stochastic_rounding_unbiased():
    fmt = FixedPointFormat(4, 0)
    table = build_stochastic_rounding_lut(fmt, in_bits=8, R=4096, seed=0)
    code = jnp.int32(0b0011_0100)  # 3.25 in 8-bit with 4 extra frac bits
    outs = np.asarray(
        [int(stochastic_round_via_lut(table, code, i)) for i in range(4096)]
    )
    assert set(outs) <= {3, 4}
    np.testing.assert_allclose(outs.mean(), 3.25, atol=0.05)


def test_stochastic_rounding_signed_two_complement():
    """Regression: signed formats used to be read as unsigned bit patterns
    and clipped to [0, code_max], zero-clamping every negative code.  The
    table must floor toward -inf, stay unbiased, and saturate at code_min."""
    fmt = FixedPointFormat(4, 0, signed=True)
    table = build_stochastic_rounding_lut(fmt, in_bits=8, R=4096, seed=0)
    assert int(table.min()) == fmt.code_min  # negative half actually present
    code = jnp.int32(-52)  # -3.25: floors to -4, rounds up to -3 w.p. 0.25
    outs = np.asarray(
        [int(stochastic_round_via_lut(table, code, i)) for i in range(4096)]
    )
    assert set(outs) <= {-4, -3}
    np.testing.assert_allclose(outs.mean(), -3.25, atol=0.05)
    # exact negative values never dither; the most negative code saturates
    exact = np.asarray(
        [int(stochastic_round_via_lut(table, jnp.int32(-64), i)) for i in range(64)]
    )
    assert set(exact) == {-4}
    lowest = np.asarray(
        [int(stochastic_round_via_lut(table, jnp.int32(-128), i)) for i in range(64)]
    )
    assert set(lowest) == {fmt.code_min}
    # positive codes are untouched by the signed handling
    pos = np.asarray(
        [int(stochastic_round_via_lut(table, jnp.int32(0b0011_0100), i))
         for i in range(4096)]
    )
    assert set(pos) <= {3, 4}
    np.testing.assert_allclose(pos.mean(), 3.25, atol=0.05)


# ---------------------------------------------------------------------------
# LUT exactness: fixed point (bitwise, via integer-valued weights)
# ---------------------------------------------------------------------------


@given(
    q=st.integers(1, 40),
    p=st.integers(1, 16),
    m=st.integers(1, 6),
    bits=st.integers(2, 6),
    frac=st.integers(0, 4),
    signed=st.booleans(),
    mode=st.sampled_from(["bitplane", "full"]),
    batch=st.integers(1, 4),
)
@settings(max_examples=40, deadline=None)
def test_lut_exact_fixed(q, p, m, bits, frac, signed, mode, batch):
    if mode == "full" and m * bits > 18:
        m = max(1, 18 // bits)
    fmt = FixedPointFormat(bits, frac, signed)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(q * 131 + p), 3)
    W = _int_weights(k1, q, p)
    b = _int_weights(k2, 1, p)[0]
    lo, hi = fmt.min_value * 1.5, fmt.max_value * 1.5
    x = jax.random.uniform(k3, (batch, q), minval=lo, maxval=hi)
    plan = LUTPlan(q, p, m, fmt, mode=mode)
    got = lut_affine_reference(x, W, b, plan)
    want = quantized_matmul_reference(x, W, b, plan)
    # integer weights + integer (scaled) inputs: fp32 arithmetic is exact
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# LUT exactness: binary16 (exact up to fp32 summation order)
# ---------------------------------------------------------------------------


@given(
    q=st.integers(1, 32),
    p=st.integers(1, 12),
    m=st.integers(1, 3),
    mode=st.sampled_from(["bitplane", "full"]),
)
@settings(max_examples=25, deadline=None)
def test_lut_exact_float16(q, p, m, mode):
    if mode == "full":
        m = 1
    fmt = Float16Format()
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(q * 17 + p), 3)
    W = _int_weights(k1, q, p)
    b = jnp.zeros((p,), jnp.float32)
    # powers of two as inputs -> products are exact in fp32
    expo = jax.random.randint(k3, (2, q), -10, 10)
    x = (2.0 ** expo.astype(jnp.float32)) * (
        jax.random.uniform(k2, (2, q)) > 0.2
    ).astype(jnp.float32)
    plan = LUTPlan(q, p, m, fmt, mode=mode)
    got = lut_affine_reference(x, W, b, plan)
    want = quantized_matmul_reference(x, W, b, plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_lut_float16_general_values_close():
    """Arbitrary fp16 inputs: same mathematical value, fp32-order tolerance."""
    fmt = Float16Format()
    q, p = 128, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    W = jax.random.normal(k1, (q, p)) / np.sqrt(q)
    b = jax.random.normal(k2, (p,)) * 0.1
    x = jax.random.uniform(k3, (8, q), maxval=4.0)
    plan = LUTPlan(q, p, 2, fmt)
    got = lut_affine_reference(x, W, b, plan)
    want = quantized_matmul_reference(x, W, b, plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-6)


def test_signed_msb_subtraction_matches_paper_schematic():
    """The negative-MSB plane scale == paper's 'shift left n-1 and subtract'."""
    fmt = FixedPointFormat(4, 0, signed=True)
    plan = LUTPlan(3, 2, 3, fmt)
    W = jnp.asarray([[1.0, 2.0], [3.0, -4.0], [5.0, 6.0]])
    x = jnp.asarray([[-8.0, 7.0, -1.0]])
    got = lut_affine_reference(x, W, None, plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W), rtol=0, atol=0)


def test_packed_code_width_and_reuse():
    """Bitplane tables are plane-independent: one table set serves all planes."""
    fmt = FixedPointFormat(5, 2)
    plan = LUTPlan(10, 3, 2, fmt)
    tables = build_luts(jnp.ones((10, 3)), plan)
    assert tables.shape == (5, 4, 3)  # k=5 chunks, 2^2 entries, p=3
    codes = pack_codes(jnp.ones((7, 10)), plan)
    assert codes.shape == (7, 5, 5)  # (batch, planes, chunks)
    assert int(codes.max()) < plan.num_entries


def test_apply_luts_bias_once_equivalent_to_b_over_k():
    """Paper stores b/k per table; we add b once — identical result."""
    fmt = FixedPointFormat(3, 1)
    q, p, m = 8, 4, 2
    plan = LUTPlan(q, p, m, fmt)
    key = jax.random.PRNGKey(3)
    W = _int_weights(key, q, p)
    b = jnp.asarray([4.0, -8.0, 12.0, 16.0])
    x = jax.random.uniform(jax.random.PRNGKey(4), (5, q), maxval=3.0)
    tables = build_luts(W, plan)
    codes = pack_codes(x, plan)
    ours = apply_luts(tables, codes, plan, bias=b)
    want = quantized_matmul_reference(x, W, b, plan)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(want), rtol=0, atol=0)
