"""Multiplier-free MoE expert execution.

Covers the ragged LUT expert path end to end: converted expert trees
(pre-stacked gate/up ``LUTGroup`` + ``w_down`` ``LUTLinear``) reproduce the
dense grouped-GEMM experts through ``moe_ffn``, through ``generate``, and
through the ``BatchingEngine`` (identical greedy token streams — the
acceptance bar), mixed dense/LUT trees execute coherently on every
projection combination, and the jitted decode step's program contains NO
``ragged_dot`` and no ``dot_general`` over expert-weight-sized operands
(multiplier-free, asserted at the jaxpr level like
``tests/test_grouped_layout.py`` does for attention).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.audit import multiplier_free_violations
from repro.configs.base import get_config
from repro.core.convert import LUTGroup, LUTLinear, convert_params
from repro.core.planner import plan_model
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_forward, model_specs
from repro.models.moe import moe_ffn, moe_specs
from repro.models.params import init_params
from repro.serve import (
    BatchingEngine,
    Request,
    generate,
    make_cache,
    make_decode_step,
)

pytestmark = pytest.mark.slow  # expert conversion + decode compiles: ~60s


def _moe_setup(seed=3):
    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, ctx, params


def _ffn_setup(seed=0):
    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 6, cfg.d_model)) * 0.5
    return cfg, p, x


def _rel_err(got, want):
    g, w = np.asarray(got, np.float32), np.asarray(want, np.float32)
    return np.abs(g - w).max() / (np.abs(w).max() + 1e-6)


# ---------------------------------------------------------------------------
# moe_ffn level: dense == LUT experts (oracle and Pallas), all mixes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 2])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_moe_ffn_lut_experts_match_dense(chunk, use_pallas):
    cfg, p, x = _ffn_setup()
    ctx = Ctx(cfg, ex=ExecCfg(remat="none", use_pallas=use_pallas))
    want, aux_want = moe_ffn(p, x, Ctx(cfg, ex=ExecCfg(remat="none")))
    lut, rep = convert_params(p, chunk_size=chunk, convert_experts=True)
    assert isinstance(lut["w_gate+w_up"], LUTGroup)  # pre-stacked pair
    assert isinstance(lut["w_down"], LUTLinear)
    got, aux_got = moe_ffn(lut, x, ctx)
    # routing runs on the raw router weights: aux loss is identical and the
    # output differs only by the fp16 input quantisation of the experts
    # (+ the converted shared-expert branch)
    np.testing.assert_allclose(float(aux_got), float(aux_want), rtol=1e-6)
    assert _rel_err(got, want) < 0.02


def test_moe_ffn_mixed_dense_lut_members_execute_coherently():
    """The old detection probed only w_gate: a plan converting only w_down
    slipped a pytree node into ragged_dot.  Every projection combination
    must now execute, each member on its own path."""
    cfg, p, x = _ffn_setup(seed=5)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    want, _ = moe_ffn(p, x, ctx)

    combos = [
        ("w_down",),  # the regression: down-only conversion
        ("w_gate", "w_up"),  # pre-stacked pair, dense down
        ("w_gate",),  # a lone gate: no group, dense up/down
        ("w_gate", "w_up", "w_down"),
    ]
    for members in combos:
        # expert-stack members only (the shared-expert MLP has 2-D w_down)
        def pred(path, node, m=members):
            return path[-1] in m and node["w"].ndim >= 3

        mp = plan_model(
            params=p,
            max_lut_bytes=float("inf"),
            max_chunk=1,
            predicate=pred,
            convert_experts=True,
        )
        lut, rep = convert_params(
            p, plan=mp, predicate=pred, convert_experts=True
        )
        assert rep.converted == len(members), members
        got, _ = moe_ffn(lut, x, ctx)
        assert _rel_err(got, want) < 0.02, members


def test_moe_ffn_group_only_gate_up_share_one_packing():
    """The pre-stacked pair's fused dispatch is bit-identical to executing
    the two members separately against their table slices."""
    cfg, p, x = _ffn_setup(seed=7)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    lut, _ = convert_params(p, chunk_size=1, convert_experts=True)
    fused, _ = moe_ffn(lut, x, ctx)
    # split the stored group into two lone LUTLinear members
    group = lut["w_gate+w_up"]
    split = {k: v for k, v in lut.items() if k != "w_gate+w_up"}
    for g, name in enumerate(group.members):
        split[name] = LUTLinear(tables=group.tables[:, g], plan=group.plan)
    unfused, _ = moe_ffn(split, x, ctx)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


# ---------------------------------------------------------------------------
# Engine-level acceptance: identical greedy streams, multiplier-free jaxpr
# ---------------------------------------------------------------------------

_PROMPTS = ((1, 2, 3, 4), (5, 6, 7), (9, 10, 11, 12, 13))


def _run_engine(params, ctx, max_new=4):
    eng = BatchingEngine(params, ctx, num_slots=2, max_len=32)
    reqs = [
        Request(uid=i, prompt=jnp.asarray(p, jnp.int32), max_new=max_new)
        for i, p in enumerate(_PROMPTS)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.uid: r.generated for r in reqs}


def test_engine_moe_lut_equals_dense_greedy():
    """A tiny qwen2-moe config served with convert_experts=True produces
    greedy token streams identical to dense experts."""
    cfg, ctx, params = _moe_setup()
    lut, rep = convert_params(params, chunk_size=1, convert_experts=True)
    assert rep.grouped > 0
    gctx = dataclasses.replace(
        ctx, ex=dataclasses.replace(ctx.ex, lut_grouped=True)
    )
    dense = _run_engine(params, ctx)
    lut_streams = _run_engine(lut, gctx)
    assert dense == lut_streams


def test_generate_moe_lut_matches_dense_greedy():
    cfg, ctx, params = _moe_setup(seed=11)
    lut, _ = convert_params(params, chunk_size=1, convert_experts=True)
    tokens = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    want = generate(params, ctx, tokens, max_new=4, max_len=32)
    got = generate(lut, ctx, tokens, max_new=4, max_len=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_moe_decode_step_jaxpr_is_multiplier_free():
    """The acceptance bar: the jitted decode step over a converted-experts
    tree lowers to a program with NO ragged_dot anywhere and no dot_general
    touching an operand as large as even one expert-stack weight (the
    router / shared-gate / attention-score contractions are small and
    allowed; all projections execute as LUT gathers)."""
    cfg, _, params = _moe_setup()
    lut, rep = convert_params(params, chunk_size=1, convert_experts=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    decode = make_decode_step(ctx)
    cache = make_cache(cfg, 1, 16, ctx)
    jaxpr = jax.make_jaxpr(decode)(lut, cache, jnp.zeros((1, 1), jnp.int32))

    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    min_expert_w = E * d * f  # elements of one (E, d, f) expert projection
    offenders = multiplier_free_violations(
        jaxpr, min_operand_elems=min_expert_w
    )
    assert not offenders, (
        f"decode_step still multiplies over expert weights: {offenders} "
        f"(threshold {min_expert_w} elems)"
    )
