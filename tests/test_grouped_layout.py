"""Conversion-time grouped table layout.

Covers the pre-stacked ``LUTGroup`` layout end to end: conversion emits
kernel-ready ``(G, k, E, p)`` leaves, plans are explicit static metadata
(no shape sniffing — the chunk-7 unsigned fixed-point vs chunk-1 signed
fp16 entry-count collision is a regression test here), a grouped decode
step contains ZERO per-step stack/concat of table-sized operands at the
jaxpr level, plans never split groups, planner/converter eligibility
mismatches raise, and the whole layout round-trips through
``save_checkpoint(aux=)`` onto an abstract template (elastic restore)
while serving identically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.audit import zero_copy_violations
from repro.configs.base import get_config
from repro.core.convert import LUTGroup, LUTLinear, convert_params
from repro.core.lut import LUTPlan, quantized_matmul_reference
from repro.core.planner import ModelPlan, plan_model
from repro.core.quantize import FixedPointFormat, Float16Format
from repro.dist.checkpoint import load_aux, restore_checkpoint, save_checkpoint
from repro.models.layers import Ctx, ExecCfg, fused_linears, linear
from repro.models.model import model_specs
from repro.models.params import abstract_params, init_params
from repro.serve import generate, make_cache, make_decode_step


def _lm(arch="granite_8b", seed=0):
    cfg = get_config(arch, reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


def _lut_groups(tree) -> list:
    out = []
    if isinstance(tree, LUTGroup):
        out.append(tree)
    elif isinstance(tree, dict):
        for v in tree.values():
            out.extend(_lut_groups(v))
    return out


# ---------------------------------------------------------------------------
# Layout: conversion pre-stacks sibling groups
# ---------------------------------------------------------------------------


def test_convert_emits_prestacked_groups_matching_flat_layout():
    """Each LUTGroup leaf is exactly the member tables stacked on the group
    axis (just before the chunk axis) — byte-identical to the flat
    per-projection conversion under the same plan."""
    _, params = _lm()
    grouped, grep = convert_params(params, chunk_size=1)
    flat, frep = convert_params(params, chunk_size=1, group_siblings=False)
    assert grep.grouped > 0
    assert grep.converted == frep.converted  # grouping changes layout only
    assert grep.table_bytes == frep.table_bytes

    def walk(g, f):
        if isinstance(g, LUTGroup):
            assert g.tables.ndim == f[g.members[0]].tables.ndim + 1
            for i, name in enumerate(g.members):
                member = f[name]
                assert isinstance(member, LUTLinear)
                assert g.plan == member.plan
                got = np.asarray(g.tables[..., i, :, :, :])
                np.testing.assert_array_equal(got, np.asarray(member.tables))
            return
        if isinstance(g, dict):
            for k, v in g.items():
                walk(v, f if isinstance(v, LUTGroup) else f[k])

    walk(grouped, flat)


def test_mixed_bias_group_fuses_and_matches_per_member():
    """A group where only some members carry a bias still fuses (per-member
    bias leaves) and reproduces the per-projection path bit-for-bit."""
    q, p = 24, 16
    kw, kb, kx = jax.random.split(jax.random.PRNGKey(1), 3)
    parent = {
        "ffn": {
            "w_gate": {
                "w": jax.random.normal(kw, (q, p)),
                "b": jax.random.normal(kb, (p,)),
            },
            "w_up": {"w": jax.random.normal(kb, (q, p))},
        }
    }
    grouped, rep = convert_params(parent, chunk_size=1)
    assert rep.grouped == 1
    node = grouped["ffn"]["w_gate+w_up"]
    assert isinstance(node, LUTGroup)
    assert node.members == ("w_gate", "w_up")
    assert isinstance(node.b, tuple) and node.b[1] is None

    flat, _ = convert_params(parent, chunk_size=1, group_siblings=False)
    cfg = get_config("granite_8b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    x = jax.random.normal(kx, (3, q))
    g, u = fused_linears(grouped["ffn"], ("w_gate", "w_up"), x, ctx)
    g_ref = linear(flat["ffn"]["w_gate"], x, ctx)
    u_ref = linear(flat["ffn"]["w_up"], x, ctx)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_ref))
    np.testing.assert_array_equal(np.asarray(u), np.asarray(u_ref))


# ---------------------------------------------------------------------------
# Plan metadata replaces shape sniffing (the entry-count collision)
# ---------------------------------------------------------------------------


def test_colliding_entry_counts_both_execute_correctly():
    """An unsigned fixed-point chunk-7 bitplane table and a signed-fp16
    chunk-1 table both have 2**7 entries; the retired shape-sniffing
    (`_lut_plan_for`) could only decode one of them.  With the plan stored
    on the node, both reproduce their quantised-matmul reference."""
    q, p = 12, 5
    kw, kx = jax.random.split(jax.random.PRNGKey(2))
    w = jax.random.normal(kw, (q, p))
    b = jnp.arange(p, dtype=jnp.float32) * 0.1
    cfg = get_config("granite_8b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))

    fx_plan = LUTPlan(q, p, 7, FixedPointFormat(8, 4, signed=False))
    fp_plan = LUTPlan(q, p, 1, Float16Format(signed=True))
    assert fx_plan.num_entries == fp_plan.num_entries == 2**7  # the collision

    for plan, x in [
        (fx_plan, jax.random.uniform(kx, (4, q)) * 10.0),  # unsigned range
        (fp_plan, jax.random.normal(kx, (4, q))),
    ]:
        conv, rep = convert_params(
            {"fc": {"w": w, "b": b}}, plan=ModelPlan(layers={"fc": plan})
        )
        assert rep.converted == 1
        assert conv["fc"].plan == plan  # explicit metadata, not inferred
        got = linear(conv["fc"], x, ctx)
        want = quantized_matmul_reference(x, w, b, plan)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# Group-aware planning + eligibility alignment
# ---------------------------------------------------------------------------


def test_plan_model_never_splits_groups():
    _, params = _lm()
    full = plan_model(params, float("inf"), max_chunk=2)
    half = plan_model(params, full.total_lut_bytes // 2, max_chunk=2)
    for mp in (full, half):
        assert mp.groups, "group-aware planning found no fusable siblings"
        for group in mp.groups:
            plans = {mp.layers[key] for key in group}
            assert len(plans) == 1, (group, plans)
    # groups survive the JSON round trip
    back = ModelPlan.from_json(half.to_json())
    assert back.groups == half.groups


def test_plan_entry_vetoed_by_predicate_raises():
    params = {
        "a": {"w": jnp.ones((8, 4))},
        "b": {"w": jnp.ones((8, 4))},
    }
    mp = plan_model(params, float("inf"), max_chunk=1)
    assert set(mp.layers) == {"a", "b"}
    with pytest.raises(ValueError, match="never consumed"):
        convert_params(params, plan=mp, predicate=lambda path, _: path[0] != "a")
    with pytest.raises(ValueError, match="never consumed"):
        convert_params(params, plan=mp, min_features=9)


@pytest.mark.slow  # MoE param init + expert table build: ~20s
def test_expert_plan_alignment_with_converter():
    """plan_model(convert_experts=True) and convert_params agree on expert
    eligibility; dropping the flag on the converter side raises instead of
    silently leaving planned experts dense."""
    cfg, params = _lm("qwen2_moe_a2_7b", seed=6)

    def experts_only(path, node):
        return node["w"].ndim == 4  # (L, E, q, p) expert stacks

    mp = plan_model(
        params, float("inf"), max_chunk=1,
        predicate=experts_only, convert_experts=True,
    )
    assert mp.layers and all("w_" in k.rsplit("/", 1)[-1] for k in mp.layers)
    # the expert stacks carry copies = L * E and gate/up fuse into a group
    assert all(v > 1 for v in mp.copies.values())
    assert any("w_gate" in g[0] for g in mp.groups)
    with pytest.raises(ValueError, match="never consumed"):
        convert_params(params, plan=mp, predicate=experts_only)
    lut, rep = convert_params(
        params, plan=mp, predicate=experts_only, convert_experts=True
    )
    assert rep.converted == len(mp.layers)
    assert rep.grouped > 0  # gate/up pre-stacked at conversion time
    # converted experts now EXECUTE via the ragged LUT path: the forward
    # runs and stays close to the dense-experts reference
    from repro.models.model import model_forward

    tokens = jax.random.randint(jax.random.PRNGKey(8), (1, 4), 0, cfg.vocab_size)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    want, _, _ = model_forward(params, {"tokens": tokens}, ctx)
    got, _, _ = model_forward(lut, {"tokens": tokens}, ctx)
    w, g = np.asarray(want, np.float32), np.asarray(got, np.float32)
    assert np.abs(g - w).max() / (np.abs(w).max() + 1e-6) < 0.02


# ---------------------------------------------------------------------------
# The zero-copy guarantee, at the jaxpr level
# ---------------------------------------------------------------------------


def test_decode_step_jaxpr_has_no_table_sized_concat():
    """The acceptance bar: with ``lut_grouped=True`` over the pre-stacked
    layout, tracing ``decode_step`` yields NO concatenate/stack whose
    output is as large as even one member's table — the re-stack the old
    layout paid on every decode step is gone from the program itself."""
    cfg, params = _lm()
    lut_params, rep = convert_params(params, chunk_size=1)
    assert rep.grouped > 0
    groups = _lut_groups(lut_params)
    assert groups
    min_member_elems = min(
        int(np.prod(g.tables.shape[-3:])) for g in groups
    )

    ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    decode = make_decode_step(ctx)
    cache = make_cache(cfg, 1, 16, ctx)
    tokens = jnp.zeros((1, 1), jnp.int32)
    jaxpr = jax.make_jaxpr(decode)(lut_params, cache, tokens)

    offenders = zero_copy_violations(
        jaxpr, min_out_elems=min_member_elems, primitives=("concatenate",)
    )
    assert not offenders, (
        f"decode_step concatenates table-sized operands per step: "
        f"{offenders} (threshold {min_member_elems} elems)"
    )


# ---------------------------------------------------------------------------
# plan -> convert -> checkpoint(aux) -> elastic restore -> serve
# ---------------------------------------------------------------------------


@pytest.mark.slow  # converts + compiles grouped decode twice: ~60s
def test_grouped_layout_checkpoint_restore_serve_equivalence(tmp_path):
    """The converted (grouped) tree checkpoints and restores onto an
    abstract template built from the plan alone — no original weights —
    and serves token-identically through the grouped decode path."""
    cfg, params = _lm()
    uniform = plan_model(params, float("inf"), max_chunk=2)
    mp = plan_model(params, uniform.total_lut_bytes // 2, max_chunk=2)
    lut, rep = convert_params(params, plan=mp)
    assert rep.grouped == len(mp.groups)

    ckpt = str(tmp_path / "ckpt")
    save_checkpoint(ckpt, 3, lut, aux={"model_plan": mp.to_json()})

    # restore side: only the config and the aux plan are available
    mp_back = ModelPlan.from_json(load_aux(ckpt, 3)["model_plan"])
    assert mp_back.groups == mp.groups
    template = jax.eval_shape(
        lambda p: convert_params(p, plan=mp_back)[0],
        abstract_params(model_specs(cfg)),
    )
    restored = restore_checkpoint(ckpt, 3, template)

    gctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    tokens = jax.random.randint(jax.random.PRNGKey(9), (2, 6), 0, cfg.vocab_size)
    want = generate(lut, gctx, tokens, max_new=4)
    got = generate(restored, gctx, tokens, max_new=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
