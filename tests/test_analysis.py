"""The paper's inline numbers, recomputed from our accounting formulas."""
import pytest

from repro.core.analysis import MiB, KiB, figure_curve, MLP, paper_claims
from repro.core.planner import enumerate_plans, plan_under_budget, tradeoff_curve
from repro.core.quantize import FixedPointFormat, Float16Format


@pytest.fixture(scope="module")
def claims():
    return paper_claims()


def test_linear_classifier_m14(claims):
    c = claims["linear_m14"]
    assert c["tables"] == 56
    assert abs(c["mib"] - 17.5) < 0.01  # paper: 17.5 MiB
    assert c["evals"] == 168  # paper: 168 LUT evaluations
    # paper quotes 1650 (p*n*(k-1)); our exact count p*(n*k-1) = 1670
    assert c["shift_adds"] in (1650, 1670)


def test_linear_classifier_m1(claims):
    c = claims["linear_m1"]
    assert c["tables"] == 784
    assert abs(c["kib"] - 30.6) < 0.1  # paper: ~30.6 KiB == weight footprint
    # paper: 23520 = q*n*p; exact count is p*(n*k-1) = 23510
    assert c["shift_adds"] in (23520, 23510)


def test_mlp_bitplane_exactly_matches_paper(claims):
    c = claims["mlp_bitplane"]
    assert c["tables"] == 2320  # paper: 2320 LUTs
    assert abs(c["mib"] - 162.6) < 0.05  # paper: 162.6 MiB
    assert c["shift_adds"] == 14652918  # paper: 14652918 — exact


def test_mlp_full_adds_exactly_matches_paper(claims):
    c = claims["mlp_full"]
    assert c["tables"] == 2320
    assert c["adds"] == 1330678  # paper: 1330678 — exact


def test_mlp_ref_madds(claims):
    assert claims["mlp_ref_madds"] == 1332224  # paper: 1332224 multiply-adds


def test_cnn_dense_dominates_400mib(claims):
    # paper: "total LUT size is 400 Mebibytes"; dense layers alone are 393 MiB
    assert 390 <= claims["cnn_bitplane"]["mib"] <= 410


def test_tradeoff_curve_is_monotone():
    pts = enumerate_plans(784, 10, FixedPointFormat(3, 3))
    frontier = tradeoff_curve(pts)
    sizes = [p.lut_bytes for p in frontier]
    ops = [p.shift_add_ops for p in frontier]
    assert sizes == sorted(sizes)
    assert ops == sorted(ops, reverse=True)
    assert len(frontier) >= 3


def test_plan_under_budget_picks_fewest_ops():
    plan = plan_under_budget(784, 10, FixedPointFormat(3, 3), 18 * MiB)
    assert plan.total_lut_bytes <= 18 * MiB
    # the 17.5 MiB / 56-table point should be chosen at this budget
    assert plan.chunk_size == 14


def test_figure7_curve_contains_paper_points():
    rows = figure_curve(MLP, Float16Format())
    by = {(r["mode"], r["chunk"]): r for r in rows}
    assert by[("bitplane", 1)]["shift_adds"] == 14652918
    assert by[("full", 1)]["shift_adds"] == 1330678
