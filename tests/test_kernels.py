"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.lut import LUTPlan, build_luts, pack_codes, plane_scales
from repro.core.quantize import FixedPointFormat
from repro.kernels.binary_matmul.ops import binary_matmul
from repro.kernels.binary_matmul.ref import binary_matmul_ref
from repro.kernels.bitplane_pack.ops import bitplane_pack
from repro.kernels.bitplane_pack.ref import bitplane_pack_ref
from repro.kernels.lut_affine.ops import (
    lut_affine,
    lut_affine_experts,
    lut_affine_grouped,
)
from repro.kernels.lut_affine.ref import (
    lut_affine_experts_ref,
    lut_affine_grouped_ref,
    lut_affine_ref,
)

pytestmark = pytest.mark.slow  # interpret-mode Pallas sweeps: ~45s on CPU


# ---------------------------------------------------------------------------
# lut_affine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,n,k,E,p",
    [
        (1, 1, 1, 2, 1),  # degenerate minimum
        (4, 3, 7, 8, 10),  # ragged everything
        (16, 11, 32, 64, 96),  # fp16-style planes
        (3, 4, 130, 16, 130),  # k and p beyond one block
        (130, 2, 5, 256, 257),  # batch beyond one block, odd p
    ],
)
def test_lut_affine_matches_ref(B, n, k, E, p, dtype):
    kc, kt, ks = jax.random.split(jax.random.PRNGKey(B * 7 + k), 3)
    codes = jax.random.randint(kc, (B, n, k), 0, E)
    tables = jax.random.normal(kt, (k, E, p), dtype=jnp.float32).astype(dtype)
    scales = 2.0 ** jnp.arange(n, dtype=jnp.float32)
    got = lut_affine(codes, tables, scales, interpret=True)
    want = lut_affine_ref(codes, tables, scales)
    # blocked accumulation reorders fp32 sums; scale atol to the output range
    rel = 1e-5 if dtype == jnp.float32 else 2e-2
    atol = rel * float(np.abs(np.asarray(want)).max() + 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rel, atol=atol)


def test_lut_affine_leading_dims_and_bias():
    kc, kt = jax.random.split(jax.random.PRNGKey(0))
    codes = jax.random.randint(kc, (2, 3, 4, 8), 0, 16)  # (d0, d1, n, k)
    tables = jax.random.normal(kt, (8, 16, 12))
    scales = jnp.ones((4,))
    bias = jnp.arange(12.0)
    got = lut_affine(codes, tables, scales, bias=bias, interpret=True)
    ref = lut_affine_ref(codes.reshape(6, 4, 8), tables, scales)
    want = ref.reshape(2, 3, 12) + bias
    # blocked accumulation reorders fp32 sums (same slack as matches_ref)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_lut_affine_end_to_end_exact_vs_core():
    """Kernel path == core oracle == quantised matmul, bitwise (int weights)."""
    fmt = FixedPointFormat(4, 2, signed=True)
    q, p, m = 50, 33, 3
    plan = LUTPlan(q, p, m, fmt)
    kw, kx = jax.random.split(jax.random.PRNGKey(5))
    W = jax.random.randint(kw, (q, p), -8, 8).astype(jnp.float32)
    x = jax.random.uniform(kx, (9, q), minval=-3.0, maxval=3.0)
    tables = build_luts(W, plan)
    codes = pack_codes(x, plan)
    scales = jnp.asarray(plane_scales(plan), jnp.float32)
    got = lut_affine(codes, tables, scales, interpret=True)
    xq = fmt.dequantize(fmt.quantize(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(xq @ W), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# lut_affine_grouped (fused batched decode path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "G,B,n,k,E,p",
    [
        (1, 1, 1, 1, 2, 1),  # degenerate minimum
        (3, 4, 3, 7, 8, 10),  # QKV-style group, ragged everything
        (2, 16, 11, 32, 64, 96),  # gate/up-style group, fp16 planes
        (4, 3, 4, 130, 16, 130),  # k and p beyond one block
        (2, 130, 2, 5, 256, 257),  # batch beyond one block, odd p
    ],
)
def test_lut_affine_grouped_matches_ref(G, B, n, k, E, p, dtype):
    kc, kt = jax.random.split(jax.random.PRNGKey(G * 13 + B * 7 + k), 2)
    codes = jax.random.randint(kc, (B, n, k), 0, E)
    tables = jax.random.normal(kt, (G, k, E, p), dtype=jnp.float32).astype(dtype)
    scales = 2.0 ** jnp.arange(n, dtype=jnp.float32)
    got = lut_affine_grouped(codes, tables, scales, interpret=True)
    want = lut_affine_grouped_ref(codes, tables, scales)
    # same slack as the ungrouped kernel: blocked fp32 accumulation order
    rel = 1e-5 if dtype == jnp.float32 else 2e-2
    atol = rel * float(np.abs(np.asarray(want)).max() + 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rel, atol=atol)
    # fused grid == G separate dispatches of the per-projection kernel
    per = jnp.stack(
        [lut_affine(codes, tables[g], scales, interpret=True) for g in range(G)]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(per), rtol=rel, atol=atol)


def test_lut_affine_grouped_leading_dims_and_bias():
    kc, kt = jax.random.split(jax.random.PRNGKey(1))
    codes = jax.random.randint(kc, (2, 3, 4, 8), 0, 16)  # (d0, d1, n, k)
    tables = jax.random.normal(kt, (3, 8, 16, 12))
    scales = jnp.ones((4,))
    biases = jnp.arange(36.0).reshape(3, 12)
    got = lut_affine_grouped(codes, tables, scales, biases=biases, interpret=True)
    assert got.shape == (3, 2, 3, 12)
    want = lut_affine_grouped_ref(codes.reshape(6, 4, 8), tables, scales).reshape(
        3, 2, 3, 12
    ) + biases[:, None, None, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# lut_affine_experts (ragged MoE dispatch over pre-stacked expert tables)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "E,G,T,n,k,En,p,sizes",
    [
        (1, 1, 1, 1, 1, 2, 1, (1,)),  # degenerate minimum
        (4, 2, 11, 3, 7, 8, 10, (3, 0, 6, 2)),  # gate/up stack, empty group
        (8, 1, 16, 11, 32, 64, 96, (2,) * 8),  # w_down stack, fp16 planes
        (3, 2, 130, 2, 5, 64, 129, (50, 0, 80)),  # T and p beyond one block
        (2, 2, 6, 4, 130, 16, 130, (1, 5)),  # k beyond one block, skewed
    ],
)
def test_lut_affine_experts_matches_ref(E, G, T, n, k, En, p, sizes, dtype):
    kc, kt = jax.random.split(jax.random.PRNGKey(E * 13 + T * 7 + k), 2)
    codes = jax.random.randint(kc, (T, n, k), 0, En)
    tables = jax.random.normal(kt, (E, G, k, En, p), dtype=jnp.float32).astype(dtype)
    scales = 2.0 ** jnp.arange(n, dtype=jnp.float32)
    group_sizes = jnp.asarray(sizes, jnp.int32)
    got = lut_affine_experts(codes, tables, scales, group_sizes, interpret=True)
    want = lut_affine_experts_ref(codes, tables, scales, group_sizes)
    rel = 1e-5 if dtype == jnp.float32 else 2e-2
    atol = rel * float(np.abs(np.asarray(want)).max() + 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rel, atol=atol)


def test_lut_affine_experts_equals_segmented_per_expert_dispatch():
    """The ragged grid == slicing each expert's row segment and running the
    plain grouped kernel on it (the oracle-of-oracles cross-check)."""
    E, G, n, k, En, p = 3, 2, 4, 6, 16, 12
    sizes = (4, 0, 5)
    T = sum(sizes)
    kc, kt = jax.random.split(jax.random.PRNGKey(9), 2)
    codes = jax.random.randint(kc, (T, n, k), 0, En)
    tables = jax.random.normal(kt, (E, G, k, En, p))
    scales = 0.5 ** jnp.arange(n, dtype=jnp.float32)
    got = lut_affine_experts(
        codes, tables, scales, jnp.asarray(sizes, jnp.int32), interpret=True
    )
    start = 0
    segs = []
    for e, sz in enumerate(sizes):
        if sz:
            segs.append(
                lut_affine_grouped(
                    codes[start : start + sz], tables[e], scales, interpret=True
                )
            )
        start += sz
    want = jnp.concatenate(segs, axis=1)  # (G, T, p) in expert order
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_pick_blocks_respects_vmem_budget_for_groups():
    """Regression: block selection must account for the group dim G — the
    grouped grid keeps G projections' table tiles live, so the VMEM bound
    is G * block_k * E * block_p * 4 bytes, not the per-projection bound."""
    from repro.kernels.lut_affine.ops import _VMEM_BUDGET, _pick_blocks

    shapes = [
        (7, 128, 64),
        (64, 2**12, 512),
        (32, 2**14, 96),
        (128, 2**7, 4096),
        (64, 2**12, 300),  # ragged p: shrink must stay on 128-multiples
    ]
    for G in (1, 2, 3, 8):
        for k, E, p in shapes:
            _, block_p, block_k = _pick_blocks(8, k, E, p, 11, G=G)
            assert block_p % 128 == 0, (G, k, E, p, block_p)  # Mosaic lane dim
            if G * E * 128 * 4 > _VMEM_BUDGET:
                continue  # even a minimal tile cannot fit; nothing to assert
            live = G * block_k * E * block_p * 4
            assert live <= _VMEM_BUDGET, (G, k, E, p, block_p, block_k, live)


# ---------------------------------------------------------------------------
# bitplane_pack
# ---------------------------------------------------------------------------


@given(
    B=st.integers(1, 9),
    q=st.integers(1, 70),
    m=st.integers(1, 4),
    bits=st.integers(2, 8),
    frac=st.integers(0, 4),
    signed=st.booleans(),
)
@settings(max_examples=15, deadline=None)
def test_pack_fixed_matches_ref(B, q, m, bits, frac, signed):
    x = jax.random.uniform(
        jax.random.PRNGKey(B * q), (B, q), minval=-4.0, maxval=4.0
    )
    kw = dict(kind="fixed", bits=bits, frac=frac, signed=signed, m=m)
    got = bitplane_pack(x, interpret=True, **kw)
    want = bitplane_pack_ref(x, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,q,m", [(1, 1, 1), (5, 33, 2), (8, 130, 4), (130, 16, 1)])
def test_pack_float16_matches_ref(B, q, m):
    x = jax.random.uniform(jax.random.PRNGKey(q), (B, q), maxval=100.0)
    x = x * (jax.random.uniform(jax.random.PRNGKey(q + 1), (B, q)) > 0.1)
    kw = dict(kind="float16", bits=16, frac=0, signed=False, m=m)
    got = bitplane_pack(x, interpret=True, **kw)
    want = bitplane_pack_ref(x, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_float16_subnormals():
    x = jnp.asarray([[5.96e-8, 1.2e-7, 6.0e-5, 0.0]])
    kw = dict(kind="float16", bits=16, frac=0, signed=False, m=2)
    got = bitplane_pack(x, interpret=True, **kw)
    want = bitplane_pack_ref(x, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# binary_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,n,q,p",
    [(1, 1, 1, 1), (4, 8, 100, 30), (65, 11, 300, 140), (2, 16, 513, 257)],
)
def test_binary_matmul_matches_ref(B, n, q, p, dtype):
    kp, kw = jax.random.split(jax.random.PRNGKey(n * q))
    planes = jax.random.bernoulli(kp, 0.5, (B, n, q)).astype(jnp.int8)
    W = (jax.random.normal(kw, (q, p)) / np.sqrt(q)).astype(dtype)
    scales = 0.5 ** jnp.arange(n, dtype=jnp.float32)
    got = binary_matmul(planes, W, scales, interpret=True)
    want = binary_matmul_ref(planes, W, scales)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_binary_matmul_equals_lut_path():
    """The MXU path computes the same function as the m=1 LUT path (exact,
    integer weights): validates the beyond-paper optimisation's correctness
    claim from DESIGN.md §2."""
    fmt = FixedPointFormat(5, 3, signed=True)
    q, p = 40, 17
    plan = LUTPlan(q, p, 1, fmt)
    kw, kx = jax.random.split(jax.random.PRNGKey(11))
    W = jax.random.randint(kw, (q, p), -8, 8).astype(jnp.float32)
    x = jax.random.uniform(kx, (6, q), minval=-2.0, maxval=2.0)
    codes = pack_codes(x, plan)  # (6, n, k=q) with m=1: code == bit
    scales = jnp.asarray(plane_scales(plan), jnp.float32)
    via_bmm = binary_matmul(codes.astype(jnp.int8), W, scales, interpret=True)
    tables = build_luts(W, plan)
    via_lut = lut_affine(codes, tables, scales, interpret=True)
    np.testing.assert_allclose(np.asarray(via_bmm), np.asarray(via_lut), rtol=0, atol=0)
