"""Single-process unit tests for repro.dist (the subprocess suite in
test_dist.py is the multi-device oracle; these cover the contracts that
don't need fake devices)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.dist import checkpoint as ckpt
from repro.dist.compression import compressed_psum
from repro.dist.sharding import (
    DEFAULT_RULES,
    RULE_SETS,
    ShardCtx,
    rules_without_axis,
)


# -- ShardCtx: no-mesh defaults ---------------------------------------------


def test_shardctx_no_mesh_is_inert():
    sh = ShardCtx()
    x = jnp.ones((4, 8))
    assert sh.constrain(x, "batch", None) is x
    assert sh.sharding(("batch", None), (4, 8)) is None
    assert sh.param_sharding(
        type("S", (), {"axes": ("embed",), "shape": (8,)})()
    ) is None
    assert sh.axis_size("data") == 0
    assert sh.axis_size("data", "model") == 0
    assert not sh.heads_shardable(16)
    assert sh.data_axes == ()
    assert sh.model_axes == ()


def test_shardctx_constructor_forms():
    # the three forms the consumers use: (), (mesh), (mesh, rules)
    mesh = make_mesh((1, 1), ("data", "model"))
    inner = rules_without_axis(DEFAULT_RULES, "pod")
    assert ShardCtx().mesh is None
    assert ShardCtx(mesh).rules == DEFAULT_RULES
    assert dict(ShardCtx(mesh, inner).rules)["batch"] == ("data",)


# -- RULE_SETS ---------------------------------------------------------------


def test_rule_sets_registry():
    assert set(RULE_SETS) >= {"default", "no_fsdp"}
    default = dict(RULE_SETS["default"])
    no_fsdp = dict(RULE_SETS["no_fsdp"])
    assert default["batch"] == ("pod", "data")
    assert default["mlp"] == ("model",)
    assert default["embed"] == ("data",)  # FSDP param sharding
    assert no_fsdp["embed"] == ()
    # every logical axis the models annotate has a rule in both sets
    for name in ("batch", "embed", "heads_flat", "heads", "kv_heads", "mlp",
                 "vocab", "qseq", "seq_kv", "experts", "layers"):
        assert name in default and name in no_fsdp, name


class _StubMesh:
    """spec() only reads .shape/.axis_names, so resolution semantics can be
    tested against multi-device geometries on a 1-device host."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_spec_resolution_drops_absent_and_non_dividing_axes():
    sh = ShardCtx(_StubMesh(data=2, model=4))
    # "pod" is not in this mesh: batch resolves to ("data",) alone
    assert sh.spec(("batch", None), (4, 8)) == P("data", None)
    # a dim the assignment can't divide falls back to unsharded
    assert sh.spec(("batch", None), (3, 8)) == P(None, None)
    # a mesh axis is used at most once per tensor (first dimension wins)
    assert sh.spec(("mlp", "vocab"), (8, 8)) == P("model", None)
    # multi-axis batch peels trailing axes until the dim divides
    sh3 = ShardCtx(_StubMesh(pod=2, data=2, model=4))
    assert sh3.spec(("batch",), (8,)) == P(("pod", "data"))
    assert sh3.spec(("batch",), (6,)) == P("pod")
    assert sh3.axis_size("pod", "data") == 4
    assert sh3.heads_shardable(8) and not sh3.heads_shardable(6)


# -- checkpoint --------------------------------------------------------------


def test_latest_step_empty_and_partial(tmp_path):
    missing = str(tmp_path / "nope")
    assert ckpt.latest_step(missing) is None
    empty = str(tmp_path / "empty")
    os.makedirs(empty)
    assert ckpt.latest_step(empty) is None
    # a partial (never-committed) step dir has no meta.json and is ignored
    os.makedirs(os.path.join(empty, "step_00000007"))
    assert ckpt.latest_step(empty) is None
    ckpt.save_checkpoint(empty, 3, {"x": jnp.zeros((2,))})
    assert ckpt.latest_step(empty) == 3


def test_checkpoint_preserves_exotic_dtypes(tmp_path):
    tree = {
        "bf16": jnp.full((3,), 1.5, jnp.bfloat16),
        "i8": jnp.arange(4, dtype=jnp.int8),
        "bool": jnp.array([True, False]),
    }
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, tree)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out = ckpt.restore_checkpoint(d, 1, like)
    for k in tree:
        assert out[k].dtype == tree[k].dtype, k
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))


def test_restore_rejects_mismatched_trees(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, {"x": jnp.zeros((2,))})
    try:
        ckpt.restore_checkpoint(d, 1, {"x": jnp.zeros((2,)), "y": jnp.zeros((2,))})
    except ValueError:
        pass
    else:
        raise AssertionError("leaf-count mismatch not rejected")


# -- compression -------------------------------------------------------------


def test_compressed_psum_single_device_round_trip():
    """On a 1-way axis the mean is the identity up to quantisation, and the
    residual is exactly what quantisation dropped."""
    mesh = make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    err0 = jnp.zeros_like(g)

    def body(g, e):
        return compressed_psum({"w": g}, {"w": e}, "pod")

    out, new_err = shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        axis_names={"pod"},
    )(g, err0)
    scale = float(jnp.abs(g).max()) / 127.0
    np.testing.assert_allclose(
        np.asarray(out["w"]) + np.asarray(new_err["w"]), np.asarray(g),
        rtol=0, atol=1e-6,
    )
    assert float(jnp.abs(out["w"] - g).max()) <= scale * 0.51
    assert float(jnp.abs(new_err["w"]).max()) <= scale * 0.51
    # error feedback: feeding the residual back cancels it
    out2, err2 = shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        axis_names={"pod"},
    )(g, new_err["w"])
    np.testing.assert_allclose(
        np.asarray(out2["w"]) + np.asarray(err2["w"]),
        np.asarray(g + new_err["w"]), rtol=0, atol=1e-6,
    )
