"""Interval abstract interpretation over jaxprs (the range/overflow pass).

The structural rules in :mod:`repro.audit.rules` prove what a program *is*
(no dense matmuls, no per-step table copies); this module proves what its
values can *be*.  It walks a closed jaxpr with one conservative interval
``[lo, hi]`` per array (a sound join over the array's elements), applies a
transfer function per primitive (add/sub/mul/gather/select/shift/scan/...),
and flags every *signed-integer* arithmetic equation whose ideal-arithmetic
result interval escapes its machine dtype — i.e. a potential accumulator or
index-packing overflow, found statically, before anything executes.

Soundness conventions:

* Unknown primitives and opaque ``pallas_call`` equations fall back to the
  full dtype range of their outputs (callers can supply a closed-form
  ``pallas_model`` — :func:`repro.audit.ranges.pallas_interval_model` does,
  using the per-family accumulator certificates).
* Unsigned arithmetic is never flagged: wrapping is defined behaviour in
  XLA (and the threefry PRNG depends on it).  Overflowing unsigned results
  widen to the dtype range instead.
* ``convert_element_type`` is an intentional narrowing; the result interval
  is clamped to the target dtype, never flagged.
* ``scan`` / ``while`` carries run to a fixpoint with widening: after
  :data:`MAX_FIXPOINT_ITERS` non-converged iterations the carry widens to
  dtype ranges, then one final muted-free pass collects facts.

Integer *inputs* default to ±:data:`INT_INPUT_BOUND` (``2**24``) rather
than the full dtype range: graph inputs such as token ids, cache positions,
and packed LUT codes are small by construction, and seeding them at
``int32`` range would make ``pos + 1`` a false overflow.  The bound is a
documented precondition of the certificate ("integer graph inputs fit in
24 bits"), overridable per input via explicit ``arg_intervals``.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
from jax import core as jax_core

from repro.audit.walker import OPAQUE_PRIMITIVES

# Precondition on integer graph inputs (see module docstring).
INT_INPUT_BOUND = 2**24

MAX_FIXPOINT_ITERS = 8

_INF = math.inf


@dataclasses.dataclass(frozen=True)
class Interval:
    """Conservative ``[lo, hi]`` bound on every element of an array."""

    lo: float
    hi: float

    def __post_init__(self):
        if not (self.lo <= self.hi):
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    @staticmethod
    def point(v: float) -> "Interval":
        return Interval(float(v), float(v))

    def join(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def within(self, other: "Interval") -> bool:
        return other.lo <= self.lo and self.hi <= other.hi

    @property
    def mag(self) -> float:
        """max |value| the interval admits."""
        return max(abs(self.lo), abs(self.hi))


TOP = Interval(-_INF, _INF)


@dataclasses.dataclass(frozen=True)
class OverflowFact:
    """One signed-integer equation whose ideal result escapes its dtype."""

    primitive: str
    dtype: str
    ideal: tuple[float, float]
    detail: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def dtype_interval(dtype) -> Interval:
    """The machine range of a dtype (bool ``[0,1]``, floats ±max finite)."""
    d = np.dtype(dtype)
    if d.kind == "b":
        return Interval(0.0, 1.0)
    if d.kind in "iu":
        ii = np.iinfo(d)
        return Interval(float(ii.min), float(ii.max))
    if d.kind == "f":
        try:
            fi = np.finfo(d)
            return Interval(-float(fi.max), float(fi.max))
        except (ValueError, TypeError):  # exotic float types
            return TOP
    return TOP


def default_arg_intervals(jaxpr, int_bound: int = INT_INPUT_BOUND) -> list[Interval]:
    """The documented input policy: signed ints ±``int_bound`` (clipped to
    the dtype range, so int8 stays int8), unsigned/bool/narrow floats their
    dtype range, wide floats TOP.  ``jaxpr`` is a ``ClosedJaxpr`` (or has
    ``in_avals``)."""
    out = []
    for aval in jaxpr.in_avals:
        d = np.dtype(aval.dtype)
        rng = dtype_interval(d)
        if d.kind == "i":
            out.append(
                Interval(max(rng.lo, -float(int_bound)), min(rng.hi, float(int_bound)))
            )
        elif d.kind == "f" and d.itemsize >= 4:
            out.append(TOP)
        else:
            out.append(rng)
    return out


# ---------------------------------------------------------------------------
# interval arithmetic helpers
# ---------------------------------------------------------------------------


def _mul_bound(a: float, b: float) -> float:
    # inf * 0 is nan under IEEE; in interval arithmetic it is exactly 0
    if a == 0.0 or b == 0.0:
        return 0.0
    return a * b


def _i_add(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo + b.lo, a.hi + b.hi)


def _i_sub(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo - b.hi, a.hi - b.lo)


def _i_mul(a: Interval, b: Interval) -> Interval:
    cands = [_mul_bound(x, y) for x in (a.lo, a.hi) for y in (b.lo, b.hi)]
    return Interval(min(cands), max(cands))


def _i_neg(a: Interval) -> Interval:
    return Interval(-a.hi, -a.lo)


def _i_abs(a: Interval) -> Interval:
    if a.lo >= 0:
        return a
    if a.hi <= 0:
        return _i_neg(a)
    return Interval(0.0, a.mag)


def _i_scale(a: Interval, c: float) -> Interval:
    return _i_mul(a, Interval.point(c))


def _join_all(ivals) -> Interval:
    out = None
    for iv in ivals:
        out = iv if out is None else out.join(iv)
    return TOP if out is None else out


def _shift_candidates(a: Interval, s: Interval, op) -> Interval:
    if not (0 <= s.lo and s.hi <= 64) or a.mag == _INF:
        return TOP
    cands = [
        op(int(x), int(sh))
        for x in (a.lo, a.hi)
        for sh in (s.lo, s.hi)
        if abs(x) <= 2**63
    ]
    if not cands:
        return TOP
    return Interval(float(min(cands)), float(max(cands)))


# ---------------------------------------------------------------------------
# transfer functions: (eqn, in_intervals) -> list of IDEAL out intervals
# ---------------------------------------------------------------------------


def _reduced_count(eqn) -> int:
    """Elements contracted per output element of a reduction equation."""
    n_in = math.prod(eqn.invars[0].aval.shape) or 1
    n_out = math.prod(eqn.outvars[0].aval.shape) or 1
    return max(1, n_in // n_out)


def _t_reduce_sum(eqn, ins):
    n = _reduced_count(eqn)
    return [_i_scale(ins[0], float(n)) if ins[0].lo < 0 else Interval(
        ins[0].lo, _mul_bound(float(n), ins[0].hi))]


def _t_cumsum(eqn, ins):
    n = eqn.invars[0].aval.shape[eqn.params.get("axis", 0)] or 1
    lo = min(ins[0].lo, _mul_bound(float(n), ins[0].lo))
    hi = max(ins[0].hi, _mul_bound(float(n), ins[0].hi))
    return [Interval(lo, hi)]


def _t_dot_general(eqn, ins):
    ((lhs_c, _), _) = eqn.params["dimension_numbers"]
    c = math.prod(eqn.invars[0].aval.shape[d] for d in lhs_c) or 1
    return [_i_scale(_i_mul(ins[0], ins[1]), float(c))]


def _t_clamp(eqn, ins):
    lo_in, x, hi_in = ins
    lo = min(max(x.lo, lo_in.lo), hi_in.lo)
    hi = min(max(x.hi, lo_in.hi), hi_in.hi)
    return [Interval(min(lo, hi), max(lo, hi))]


def _t_bitwise(eqn, ins):
    a, b = ins
    if a.lo < 0 or b.lo < 0 or a.hi == _INF or b.hi == _INF:
        return [dtype_interval(eqn.outvars[0].aval.dtype)]
    name = eqn.primitive.name
    if name == "and":
        return [Interval(0.0, min(a.hi, b.hi))]
    return [Interval(0.0, a.hi + b.hi)]  # or/xor: <= sum of maxima


def _t_div(eqn, ins):
    a, b = ins
    if b.lo <= 0.0 <= b.hi:
        return [TOP]
    cands = [x / y for x in (a.lo, a.hi) for y in (b.lo, b.hi) if y != 0]
    return [Interval(min(cands), max(cands))]


def _t_rem(eqn, ins):
    m = ins[1].mag
    if m == _INF:
        return [TOP]
    return [Interval(-m, m)]


def _t_exp2(eqn, ins):
    lo = 2.0 ** ins[0].lo if ins[0].lo > -_INF else 0.0
    hi = 2.0 ** ins[0].hi if ins[0].hi < 1024 else _INF
    return [Interval(lo, hi)]


def _t_exp(eqn, ins):
    lo = math.exp(ins[0].lo) if ins[0].lo > -_INF else 0.0
    hi = math.exp(ins[0].hi) if ins[0].hi < 709 else _INF
    return [Interval(lo, hi)]


def _t_iota(eqn, ins):
    n = eqn.params["shape"][eqn.params["dimension"]]
    return [Interval(0.0, float(max(n - 1, 0)))]


def _t_argminmax(eqn, ins):
    n = math.prod(eqn.invars[0].aval.shape) or 1
    return [Interval(0.0, float(n - 1))]


def _t_square(eqn, ins):
    a = _i_abs(ins[0])
    return [Interval(_mul_bound(a.lo, a.lo), _mul_bound(a.hi, a.hi))]


def _t_integer_pow(eqn, ins):
    y = int(eqn.params["y"])
    if y < 0 or y > 64:
        return [TOP]
    out = Interval.point(1.0)
    for _ in range(y):
        out = _i_mul(out, ins[0])
    return [out]


def _t_floor_ceil(eqn, ins):
    a = ins[0]
    lo = math.floor(a.lo) if a.lo > -_INF else a.lo
    hi = math.ceil(a.hi) if a.hi < _INF else a.hi
    return [Interval(float(lo), float(hi))]


def _t_top_k(eqn, ins):
    # outputs: (top values, their indices along the searched axis)
    n = eqn.invars[0].aval.shape[-1]
    return [ins[0], Interval(0.0, float(max(n - 1, 0)))]


def _t_sort(eqn, ins):
    # sort permutes each operand independently (sort_key_val / argsort carry
    # the iota as a second operand — it must keep ITS interval, not the keys')
    return list(ins)


def _scatter_rows(eqn) -> int:
    """Update rows a scatter applies — the most that can hit ONE element."""
    upd_shape = eqn.invars[2].aval.shape
    window = set(eqn.params["dimension_numbers"].update_window_dims)
    return math.prod(
        d for i, d in enumerate(upd_shape) if i not in window
    ) or 1


def _t_scatter_add(eqn, ins):
    # worst case every update row lands on the same element
    n = float(_scatter_rows(eqn))
    u = ins[2]
    return [
        Interval(
            ins[0].lo + min(0.0, _mul_bound(n, u.lo)),
            ins[0].hi + max(0.0, _mul_bound(n, u.hi)),
        )
    ]


_UNIT = lambda eqn, ins: [Interval(-1.0, 1.0)]  # noqa: E731
_ZERO_ONE = lambda eqn, ins: [Interval(0.0, 1.0)]  # noqa: E731
_PASS = lambda eqn, ins: [ins[0]] * len(eqn.outvars)  # noqa: E731
_JOIN = lambda eqn, ins: [_join_all(ins)] * len(eqn.outvars)  # noqa: E731

_TRANSFER = {
    "add": lambda eqn, ins: [_i_add(ins[0], ins[1])],
    "add_any": lambda eqn, ins: [_i_add(ins[0], ins[1])],
    "sub": lambda eqn, ins: [_i_sub(ins[0], ins[1])],
    "mul": lambda eqn, ins: [_i_mul(ins[0], ins[1])],
    "div": _t_div,
    "rem": _t_rem,
    "neg": lambda eqn, ins: [_i_neg(ins[0])],
    "abs": lambda eqn, ins: [_i_abs(ins[0])],
    "sign": lambda eqn, ins: [Interval(-1.0, 1.0)],
    "max": lambda eqn, ins: [
        Interval(max(ins[0].lo, ins[1].lo), max(ins[0].hi, ins[1].hi))
    ],
    "min": lambda eqn, ins: [
        Interval(min(ins[0].lo, ins[1].lo), min(ins[0].hi, ins[1].hi))
    ],
    "clamp": _t_clamp,
    "select_n": lambda eqn, ins: [_join_all(ins[1:])],
    "and": _t_bitwise,
    "or": _t_bitwise,
    "xor": _t_bitwise,
    "not": lambda eqn, ins: [dtype_interval(eqn.outvars[0].aval.dtype)],
    "shift_left": lambda eqn, ins: [
        _shift_candidates(ins[0], ins[1], lambda x, s: x << s)
    ],
    "shift_right_logical": lambda eqn, ins: [
        _shift_candidates(ins[0], ins[1], lambda x, s: x >> s)
        if ins[0].lo >= 0
        else dtype_interval(eqn.outvars[0].aval.dtype)
    ],
    "shift_right_arithmetic": lambda eqn, ins: [
        _shift_candidates(ins[0], ins[1], lambda x, s: x >> s)
    ],
    "reduce_sum": _t_reduce_sum,
    "reduce_max": _PASS,
    "reduce_min": _PASS,
    "reduce_and": _PASS,
    "reduce_or": _PASS,
    "cumsum": _t_cumsum,
    "cummax": _PASS,
    "dot_general": _t_dot_general,
    "iota": _t_iota,
    "argmax": _t_argminmax,
    "argmin": _t_argminmax,
    "reduce_precision": _PASS,
    "stop_gradient": _PASS,
    "copy": _PASS,
    "reshape": _PASS,
    "broadcast_in_dim": _PASS,
    "transpose": _PASS,
    "squeeze": _PASS,
    "expand_dims": _PASS,
    "rev": _PASS,
    "slice": _PASS,
    "dynamic_slice": lambda eqn, ins: [ins[0]],
    "gather": lambda eqn, ins: [ins[0]],
    "split": _PASS,
    "concatenate": _JOIN,
    "pad": lambda eqn, ins: [_join_all(ins[:2])],
    "dynamic_update_slice": lambda eqn, ins: [_join_all(ins[:2])],
    "scatter": lambda eqn, ins: [_join_all(ins[: 3 : 2])],
    "scatter-add": _t_scatter_add,
    "scatter-min": lambda eqn, ins: [_join_all(ins[: 3 : 2])],
    "scatter-max": lambda eqn, ins: [_join_all(ins[: 3 : 2])],
    "sort": _t_sort,
    "top_k": _t_top_k,
    "device_put": _PASS,
    "tanh": _UNIT,
    "sin": _UNIT,
    "cos": _UNIT,
    "erf": _UNIT,
    "logistic": _ZERO_ONE,
    "exp": _t_exp,
    "exp2": _t_exp2,
    "square": _t_square,
    "integer_pow": _t_integer_pow,
    "floor": _t_floor_ceil,
    "ceil": _t_floor_ceil,
    "round": _t_floor_ceil,
    "nextafter": _PASS,
    "real": _PASS,
    "eq": _ZERO_ONE,
    "ne": _ZERO_ONE,
    "lt": _ZERO_ONE,
    "le": _ZERO_ONE,
    "gt": _ZERO_ONE,
    "ge": _ZERO_ONE,
    "is_finite": _ZERO_ONE,
    "sqrt": lambda eqn, ins: [
        Interval(math.sqrt(max(ins[0].lo, 0.0)), math.sqrt(ins[0].hi))
        if ins[0].hi < _INF
        else Interval(0.0, _INF)
    ],
}

# Signed-integer arithmetic worth flagging when its ideal interval escapes
# the machine dtype.  Deliberately excludes conversions/bitcasts (narrowing
# is intentional) and unsigned ops (wrapping is defined).
_FLAGGED = frozenset(
    {
        "add",
        "add_any",
        "sub",
        "mul",
        "dot_general",
        "reduce_sum",
        "cumsum",
        "scatter-add",
        "shift_left",
        "integer_pow",
        "square",
    }
)

_CALL_PRIMS = frozenset(
    {
        "pjit",
        "closed_call",
        "core_call",
        "remat",
        "checkpoint",
        "custom_jvp_call",
        "custom_vjp_call",
        "custom_vjp_call_jaxpr",
        "remat2",
    }
)


def _sub_jaxpr(v):
    if isinstance(v, jax_core.ClosedJaxpr):
        return v
    if isinstance(v, jax_core.Jaxpr):
        return jax_core.ClosedJaxpr(v, ())
    return None


def _const_interval(c) -> Interval:
    try:
        arr = np.asarray(c)
        if arr.size == 0:
            return Interval.point(0.0)
        if arr.dtype.kind in "biuf":
            lo = float(np.min(arr))
            hi = float(np.max(arr))
            if math.isnan(lo) or math.isnan(hi):
                return TOP
            return Interval(lo, hi)
    except (TypeError, ValueError, RuntimeError):
        pass
    return TOP


class _Interp:
    """One interpretation run: env management, fixpoints, fact collection."""

    def __init__(self, pallas_model=None):
        self.pallas_model = pallas_model
        self.facts: list[OverflowFact] = []
        self._mute = 0  # >0 while iterating a not-yet-converged fixpoint

    # -- env ----------------------------------------------------------------
    def _read(self, env, v) -> Interval:
        if isinstance(v, jax_core.Literal):
            return _const_interval(v.val)
        got = env.get(v)
        return got if got is not None else dtype_interval(v.aval.dtype)

    def _write(self, env, v, ideal: Interval, name: str):
        if isinstance(v, jax_core.DropVar):
            return
        d = np.dtype(v.aval.dtype)
        machine = dtype_interval(d)
        if d.kind == "i" and not ideal.within(machine):
            if name in _FLAGGED and not self._mute:
                self.facts.append(
                    OverflowFact(
                        primitive=name,
                        dtype=str(d),
                        ideal=(ideal.lo, ideal.hi),
                        detail=(
                            f"{name} -> {d} {v.aval.shape}: ideal range "
                            f"[{ideal.lo:.6g}, {ideal.hi:.6g}] escapes "
                            f"[{machine.lo:.0f}, {machine.hi:.0f}]"
                        ),
                    )
                )
            env[v] = machine  # wrapped value can be anywhere in the dtype
        elif d.kind in "ub" and not ideal.within(machine):
            env[v] = machine
        else:
            env[v] = ideal

    # -- control flow -------------------------------------------------------
    def _run_cond(self, eqn, ins) -> list[Interval]:
        branch_outs = [
            self.run(br, ins[1:]) for br in eqn.params["branches"]
        ]
        return [_join_all(outs) for outs in zip(*branch_outs)]

    def _fixpoint(self, body, n_carry: int, init: list[Interval], eqn):
        """Join-until-stable carry loop with widening; returns final carry
        plus the last body outputs (for scan's stacked ys)."""
        carry = list(init)
        outs = None
        self._mute += 1
        try:
            for _ in range(MAX_FIXPOINT_ITERS):
                outs = body(carry)
                new = [c.join(o) for c, o in zip(carry, outs[:n_carry])]
                if new == carry:
                    break
                carry = new
            else:
                carry = [
                    dtype_interval(v.aval.dtype)
                    for v in eqn.outvars[:n_carry]
                ]
        finally:
            self._mute -= 1
        outs = body(carry)  # one unmuted pass over the stabilised carry
        return carry, outs

    def _run_scan(self, eqn, ins) -> list[Interval]:
        p = eqn.params
        nc, ncarry = p["num_consts"], p["num_carry"]
        consts, init, xs = ins[:nc], ins[nc : nc + ncarry], ins[nc + ncarry :]
        body_jaxpr = p["jaxpr"]

        def body(carry):
            return self.run(body_jaxpr, consts + carry + xs)

        carry, outs = self._fixpoint(body, ncarry, init, eqn)
        return carry + outs[ncarry:]

    def _run_while(self, eqn, ins) -> list[Interval]:
        p = eqn.params
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        body_consts = ins[cn : cn + bn]
        init = ins[cn + bn :]
        body_jaxpr = p["body_jaxpr"]

        def body(carry):
            return self.run(body_jaxpr, body_consts + carry)

        carry, _ = self._fixpoint(body, len(init), init, eqn)
        return carry

    # -- main loop ----------------------------------------------------------
    def run(self, jaxpr, arg_intervals) -> list[Interval]:
        closed = _sub_jaxpr(jaxpr)
        if closed is None:
            raise TypeError(f"expected a jaxpr, got {type(jaxpr)!r}")
        inner = closed.jaxpr
        if len(arg_intervals) != len(inner.invars):
            raise ValueError(
                f"got {len(arg_intervals)} arg intervals for "
                f"{len(inner.invars)} jaxpr inputs"
            )
        env: dict = {}
        for v, c in zip(inner.constvars, closed.consts):
            env[v] = _const_interval(c)
        for v, iv in zip(inner.invars, arg_intervals):
            env[v] = iv

        for eqn in inner.eqns:
            name = eqn.primitive.name
            ins = [self._read(env, v) for v in eqn.invars]
            if name in OPAQUE_PRIMITIVES:
                outs = None
                if self.pallas_model is not None:
                    outs = self.pallas_model(eqn, ins)
                if outs is None:
                    outs = [dtype_interval(v.aval.dtype) for v in eqn.outvars]
            elif name == "cond":
                outs = self._run_cond(eqn, ins)
            elif name == "scan":
                outs = self._run_scan(eqn, ins)
            elif name == "while":
                outs = self._run_while(eqn, ins)
            elif name in _CALL_PRIMS:
                sub = None
                for v in eqn.params.values():
                    sub = _sub_jaxpr(v)
                    if sub is not None:
                        break
                if sub is not None and len(sub.jaxpr.invars) == len(ins):
                    outs = self.run(sub, ins)
                else:
                    outs = [dtype_interval(v.aval.dtype) for v in eqn.outvars]
            elif name == "convert_element_type":
                d = dtype_interval(eqn.outvars[0].aval.dtype)
                outs = [
                    Interval(
                        min(max(ins[0].lo, d.lo), d.hi),
                        max(min(ins[0].hi, d.hi), d.lo),
                    )
                ]
            else:
                fn = _TRANSFER.get(name)
                if fn is None:
                    outs = [dtype_interval(v.aval.dtype) for v in eqn.outvars]
                else:
                    try:
                        outs = fn(eqn, ins)
                    except (KeyError, IndexError, ValueError, OverflowError):
                        outs = [
                            dtype_interval(v.aval.dtype) for v in eqn.outvars
                        ]
            if len(outs) != len(eqn.outvars):  # malformed transfer: widen
                outs = [dtype_interval(v.aval.dtype) for v in eqn.outvars]
            for v, iv in zip(eqn.outvars, outs):
                self._write(env, v, iv, name)

        return [self._read(env, v) for v in inner.outvars]


def interval_eval(
    jaxpr,
    arg_intervals: list[Interval] | None = None,
    *,
    pallas_model=None,
) -> tuple[list[Interval], list[OverflowFact]]:
    """Propagate intervals through ``jaxpr``; return output intervals plus
    every signed-integer overflow fact found on the way.

    ``arg_intervals`` defaults to :func:`default_arg_intervals`'s policy.
    ``pallas_model(eqn, in_intervals) -> list[Interval] | None`` supplies
    closed-form bounds for opaque ``pallas_call`` outputs.
    """
    if arg_intervals is None:
        arg_intervals = default_arg_intervals(jaxpr)
    interp = _Interp(pallas_model=pallas_model)
    outs = interp.run(jaxpr, arg_intervals)
    return outs, interp.facts
