"""Structural invariant rules over traced programs and converted trees.

Each rule returns a list of :class:`Violation` (empty == the invariant
holds) instead of asserting, so the same predicates serve three callers:
the jaxpr acceptance tests, the ``python -m repro.audit`` CLI, and the CI
gate diffing the committed manifest.

Rule classes
------------
* :func:`multiplier_free_violations` — the paper's contract: no
  ``ragged_dot`` anywhere, and no ``dot_general`` / conv / ``mul`` whose
  operand is a planned weight (shape-suffix match against the plan's
  ``(q, p)`` projections) or a stored table leaf.  Scalar and
  activation-sized multiplies pass by construction — they match neither a
  weight nor a table shape.
* :func:`zero_copy_violations` — the PR 3 layout contract: a decode step
  never rebuilds a table at trace level, i.e. no ``concatenate`` (which
  ``stack`` lowers to), ``transpose``, or ``copy`` whose *output* is
  shaped like a stored table leaf.
* :func:`plan_consistency_violations` — the ``ModelPlan`` and the
  converted tree tell the same story: every plan entry is consumed by
  exactly the leaves it planned, families and per-layer plans match,
  materialised table bytes equal ``total_lut_bytes``, and any tuned
  ``blocks`` are legal under the kernels' VMEM budget.

Shape-suffix matching (not exact-shape matching) is what makes the rules
robust to stacking: a scan-stacked ``(L, q, p)`` dense fallback, an
expert-stacked ``(L, E, q, p)`` one, and a bare ``(q, p)`` weight all end
in the planned ``(q, p)`` — while the LUT pipeline's own small
contractions (plane-scale accumulates, rope rotations, attention scores)
match nothing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

from repro.audit.walker import as_eqns

# Primitives that multiply operands elementwise or as contractions.
_CONTRACTIONS = ("dot_general", "conv_general_dilated")
_ZERO_COPY_PRIMITIVES = ("concatenate", "transpose", "copy")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule breach, serialisable into the audit manifest."""

    rule: str
    primitive: str
    detail: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d) -> "Violation":
        return cls(str(d["rule"]), str(d["primitive"]), str(d["detail"]))


def _has_suffix(shape: Sequence[int], suffix: Sequence[int]) -> bool:
    return len(shape) >= len(suffix) and tuple(shape[-len(suffix):]) == tuple(suffix)


def _matches_any(shape: Sequence[int], suffixes: Iterable[Sequence[int]]) -> bool:
    return any(_has_suffix(shape, s) for s in suffixes)


def planned_weight_shapes(mplan) -> frozenset[tuple[int, int]]:
    """Forbidden ``(q, p)`` suffixes for a plan: every planned projection's
    weight shape and its transpose (a dense fallback may present either)."""
    out = set()
    for plan in mplan.layers.values():
        out.add((plan.in_features, plan.out_features))
        out.add((plan.out_features, plan.in_features))
    return frozenset(out)


def table_leaf_shapes(tree) -> frozenset[tuple[int, ...]]:
    """Forbidden table suffixes: the trailing table-set dims of every stored
    ``LUTLinear`` / ``LUTGroup`` leaf (one set per scan/expert copy), with
    the group axis included for grouped leaves — exactly the shape a
    per-step re-stack or table transpose would produce."""
    from repro.core.convert import LUTGroup, LUTLinear

    out: set[tuple[int, ...]] = set()

    def walk(node):
        if isinstance(node, (LUTLinear, LUTGroup)):
            ndim = 2 if node.plan.table_family == "tl1" else 3
            if isinstance(node, LUTGroup):
                ndim += 1
            out.add(tuple(node.tables.shape[-ndim:]))
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)

    walk(tree)
    return frozenset(out)


def multiplier_free_violations(
    jaxpr,
    *,
    weight_shapes: Iterable[Sequence[int]] = (),
    table_shapes: Iterable[Sequence[int]] = (),
    exempt_dims: Iterable[int] = (),
    min_operand_elems: int | None = None,
) -> list[Violation]:
    """The paper's contract: the program contains no multiplier over
    weight- or table-shaped operands.

    ``ragged_dot`` is always a violation (it exists only to contract
    expert weight stacks).  ``dot_general`` / conv equations are flagged
    when an operand shape ends in a ``weight_shapes`` suffix or (when
    ``min_operand_elems`` is given) when any operand reaches that element
    count — the threshold form the pre-audit tests used.  ``mul`` is
    flagged on weight- or table-shaped operands only, which is the
    allowlist for scalar/activation muls.  Operands carrying a dim listed
    in ``exempt_dims`` (e.g. the tied-embedding vocab) are skipped.
    """
    weight_shapes = tuple(tuple(s) for s in weight_shapes)
    table_shapes = tuple(tuple(s) for s in table_shapes)
    exempt = frozenset(exempt_dims)
    out = []
    for eqn in as_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "ragged_dot":
            out.append(
                Violation("multiplier_free", name, "ragged expert contraction")
            )
            continue
        if name not in _CONTRACTIONS and name != "mul":
            continue
        shapes = [tuple(v.aval.shape) for v in eqn.invars]
        if exempt and any(d in exempt for s in shapes for d in s):
            continue
        if name in _CONTRACTIONS:
            hit = any(_matches_any(s, weight_shapes) for s in shapes)
            if not hit and min_operand_elems is not None:
                hit = max(math.prod(s) for s in shapes) >= min_operand_elems
        else:  # mul: only weight/table-shaped operands are forbidden
            forbidden = weight_shapes + table_shapes
            hit = any(_matches_any(s, forbidden) for s in shapes)
        if hit:
            out.append(Violation("multiplier_free", name, f"operands {shapes}"))
    return out


def zero_copy_violations(
    jaxpr,
    *,
    table_shapes: Iterable[Sequence[int]] = (),
    min_out_elems: int | None = None,
    primitives: Sequence[str] = _ZERO_COPY_PRIMITIVES,
) -> list[Violation]:
    """The PR 3 layout contract: the traced step never materialises a
    table-shaped value via ``concatenate`` (stack), ``transpose``, or
    ``copy`` — the stored pre-stacked leaves are consumed as-is.

    Flags equations whose *output* shape ends in a ``table_shapes`` suffix
    or (when ``min_out_elems`` is given) reaches that element count.
    """
    table_shapes = tuple(tuple(s) for s in table_shapes)
    out = []
    for eqn in as_eqns(jaxpr):
        if eqn.primitive.name not in primitives:
            continue
        shapes = [tuple(v.aval.shape) for v in eqn.outvars]
        hit = any(_matches_any(s, table_shapes) for s in shapes)
        if not hit and min_out_elems is not None:
            hit = max(math.prod(s) for s in shapes) >= min_out_elems
        if hit:
            out.append(
                Violation("zero_copy", eqn.primitive.name, f"outputs {shapes}")
            )
    return out


def plan_consistency_violations(mplan, tree, *, batch: int = 1) -> list[Violation]:
    """The plan and the converted tree agree.

    Checks, per the ``ModelPlan`` contract:
    * every plan entry is consumed by a converted leaf, and every leaf's
      layer appears in the plan (no silent dense leftovers);
    * each leaf carries the exact per-layer plan object (family included);
    * the bytes actually materialised across table leaves equal
      ``mplan.total_lut_bytes`` (the PR 5 copies accounting);
    * any tuned ``blocks`` riding a plan are legal under the kernels'
      4 MiB VMEM budget (``kernels.lut_affine.autotune.blocks_fit_vmem``).
    """
    from repro.core.convert import LUTGroup, LUTLinear
    from repro.core.planner import path_key
    from repro.kernels.lut_affine.autotune import TunePoint, blocks_fit_vmem

    out = []
    consumed: dict[str, object] = {}
    table_bytes = 0

    def walk(node, path):
        nonlocal table_bytes
        if isinstance(node, LUTLinear):
            consumed[path_key(path)] = node
            table_bytes += node.tables.size * node.tables.dtype.itemsize
        elif isinstance(node, LUTGroup):
            for name in node.members:
                consumed[path_key(path[:-1] + (name,))] = node
            table_bytes += node.tables.size * node.tables.dtype.itemsize
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (k,))

    walk(tree, ())

    for key in sorted(set(mplan.layers) - set(consumed)):
        out.append(
            Violation(
                "plan_consistency", "never_consumed", f"plan entry {key!r}"
            )
        )
    for key in sorted(set(consumed) - set(mplan.layers)):
        out.append(
            Violation(
                "plan_consistency", "unplanned_leaf", f"converted leaf {key!r}"
            )
        )

    group_sizes: dict[str, int] = {}
    for group in mplan.groups:
        for key in group:
            group_sizes[key] = len(group)
    for key, node in sorted(consumed.items()):
        plan = mplan.layers.get(key)
        if plan is None:
            continue
        if node.plan != plan:
            out.append(
                Violation(
                    "plan_consistency",
                    "plan_mismatch",
                    f"{key!r}: leaf plan {node.plan} != planned {plan}",
                )
            )
        if plan.blocks is not None:
            pt = TunePoint.from_plan(plan, batch, G=group_sizes.get(key, 1))
            if not blocks_fit_vmem(pt, plan.blocks):
                out.append(
                    Violation(
                        "plan_consistency",
                        "blocks_over_vmem",
                        f"{key!r}: blocks {plan.blocks} bust the VMEM "
                        f"budget at point {pt}",
                    )
                )

    if table_bytes != mplan.total_lut_bytes:
        out.append(
            Violation(
                "plan_consistency",
                "byte_mismatch",
                f"materialised {table_bytes} table bytes != plan "
                f"total_lut_bytes {mplan.total_lut_bytes}",
            )
        )
    return out
