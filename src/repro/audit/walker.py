"""Shared recursive jaxpr walker.

One walker for every structural audit in the repo (the rule classes in
``audit.rules`` and the jaxpr-level acceptance tests).  It descends into
every sub-jaxpr an equation carries in its params — ``scan`` / ``while`` /
``cond`` branches, ``pjit``, ``custom_jvp``/``custom_vjp`` callables,
``remat`` (``checkpoint``) bodies — because all of them store their bodies
as ``Jaxpr`` / ``ClosedJaxpr`` values (possibly inside lists or tuples).

``pallas_call`` is the one exception: its body is a hand-written kernel
whose inner program is *supposed* to gather, multiply indices, and copy
tiles — auditing it with graph-level rules would be meaningless.  The
walker surfaces the ``pallas_call`` equation itself as an opaque audited
leaf and does not descend, so a census counts kernel dispatches, not
kernel internals.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterator

from jax import core as jax_core

# Primitives surfaced as opaque leaves: yielded, never descended into.
OPAQUE_PRIMITIVES = frozenset({"pallas_call"})


def iter_eqns(jaxpr) -> Iterator:
    """Yield every equation in ``jaxpr`` and (recursively) its sub-jaxprs.

    Accepts a ``Jaxpr`` or a ``ClosedJaxpr`` (``jax.make_jaxpr`` returns
    the latter).  Equations whose primitive is in :data:`OPAQUE_PRIMITIVES`
    are yielded but not descended into.
    """
    if isinstance(jaxpr, jax_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name in OPAQUE_PRIMITIVES:
            continue
        for v in eqn.params.values():
            sub = v if isinstance(v, (list, tuple)) else (v,)
            for s in sub:
                if isinstance(s, (jax_core.ClosedJaxpr, jax_core.Jaxpr)):
                    yield from iter_eqns(s)


def as_eqns(jaxpr_or_eqns) -> list:
    """Materialise the recursive equation list once.

    Pass-through for an already-materialised ``list`` of equations, so every
    rule pass over one audit point shares a single walk of the trace
    (``points.trace_point`` builds the lists; ``--point`` runs lean on them).
    """
    if isinstance(jaxpr_or_eqns, list):
        return jaxpr_or_eqns
    return list(iter_eqns(jaxpr_or_eqns))


def op_census(jaxpr) -> dict[str, int]:
    """Primitive name -> occurrence count over the whole (recursive) program.

    Sorted by name so the result is JSON-stable — the audit manifest diffs
    censuses across commits to catch silent graph drift.  Accepts a jaxpr
    or a pre-walked equation list (see :func:`as_eqns`).
    """
    counts = Counter(eqn.primitive.name for eqn in as_eqns(jaxpr))
    return dict(sorted(counts.items()))
