"""Compiled-artifact checks: cache donation + collective traffic.

The jaxpr rules see the program *before* XLA; donation is a property of
the compiled executable.  ``serve.generate`` and the ``BatchingEngine``
jit their steps with the cache argument donated so decode updates the KV
rectangle in place — if a refactor drops the aliasing (a stray
``device_put``, a cache leaf returned through a reshaping copy), every
step silently pays a full cache copy.  This module parses the
``input_output_alias`` attribute off ``compiled.as_text()`` and verifies
the cache's flat parameter slots all alias an output buffer.

Collective traffic reuses :func:`repro.launch.hlo_analysis.collective_stats`
(the partitioned-module ring model) so the audit manifest records, per
audited graph, what the program moves over links — zero on the
single-device CI points, and a drift signal once sharded points land.
"""
from __future__ import annotations

import re

from repro.audit.rules import Violation
from repro.launch.hlo_analysis import collective_stats

_ALIAS_ATTR = "input_output_alias={"
_ALIAS_PARAM_RE = re.compile(r":\s*\((\d+)")


def aliased_param_indices(hlo_text: str) -> frozenset[int]:
    """Flat parameter indices the executable aliases to output buffers.

    The HloModule header carries ``input_output_alias={ {out}: (param,
    {index}, may-alias), ... }`` with nested braces, so this brace-matches
    the attribute block before pulling the parameter numbers out.
    """
    start = hlo_text.find(_ALIAS_ATTR)
    if start < 0:
        return frozenset()
    i = start + len(_ALIAS_ATTR)
    depth = 1
    j = i
    while depth and j < len(hlo_text):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
        j += 1
    block = hlo_text[i : j - 1]
    return frozenset(int(m) for m in _ALIAS_PARAM_RE.findall(block))


def donation_violations(
    hlo_text: str, cache_param_indices: range
) -> list[Violation]:
    """Every cache leaf's flat parameter slot must be aliased (donated)."""
    aliased = aliased_param_indices(hlo_text)
    missing = sorted(set(cache_param_indices) - aliased)
    if not missing:
        return []
    return [
        Violation(
            "donation",
            "undonated_cache_leaf",
            f"cache params {missing} not in input_output_alias "
            f"(aliased: {sorted(aliased)})",
        )
    ]


def compiled_report(hlo_text: str, cache_param_indices: range) -> dict:
    """Donation verdict + collective traffic for one compiled graph."""
    return {
        "donation": [
            v.to_json() for v in donation_violations(hlo_text, cache_param_indices)
        ],
        "aliased_params": sorted(aliased_param_indices(hlo_text)),
        "collectives": collective_stats(hlo_text).to_dict(),
    }
