"""CLI: audit the committed config matrix, gate on the baseline.

Modes (mutually exclusive):

* default      — build the manifest, print a summary (and ``--out`` it)
* ``--check``  — rebuild fresh, fail on any rule violation, op-census
                 drift vs ``--baseline``, or missing point (the CI gate)
* ``--write``  — regenerate ``--baseline`` after a reviewed graph change;
                 refuses to snapshot a manifest with violations

``--no-compile`` skips the AOT donation/collective pass for a fast
jaxpr-only run (not valid for ``--check``/``--write``: the committed
baseline always carries the compiled report).  ``--point NAME`` (repeat
for several) restricts the run to the named points; under ``--check``
the baseline comparison restricts to the same selection.  The fast local
loop is ``--point X --no-compile``.  ``--point`` is not valid with
``--write`` — a partial baseline would silently drop the other gates.
"""
from __future__ import annotations

import argparse
import sys
from typing import Sequence

from repro.audit.manifest import (
    ManifestError,
    build_manifest,
    diff_manifests,
    load_manifest,
    manifest_violations,
    write_manifest,
)

_DEFAULT_BASELINE = "benchmarks/baselines/audit.json"


def _summarise(manifest: dict) -> str:
    lines = []
    for name, entry in sorted(manifest["points"].items()):
        n_viol = sum(len(v) for v in entry["rules"].values())
        plan = entry["plan"]
        census = entry["census"]["decode"]
        lines.append(
            f"  {name}: {plan['layers']} layers "
            f"({'+'.join(plan['families'])}), "
            f"{plan['total_lut_bytes'] / 2**20:.1f} MiB tables, "
            f"{sum(census.values())} decode eqns, "
            f"{n_viol} violations"
        )
    return "\n".join(lines)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.audit", description=__doc__.splitlines()[0]
    )
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument(
        "--check", action="store_true", help="gate against the baseline"
    )
    mode.add_argument(
        "--write", action="store_true", help="regenerate the baseline"
    )
    ap.add_argument("--baseline", default=_DEFAULT_BASELINE)
    ap.add_argument("--out", help="also write the fresh manifest here")
    ap.add_argument(
        "--no-compile",
        action="store_true",
        help="skip the AOT donation/collective pass (default mode only)",
    )
    ap.add_argument(
        "--point",
        action="append",
        metavar="NAME",
        help="restrict to the named audit point (repeatable)",
    )
    args = ap.parse_args(argv)
    if args.no_compile and (args.check or args.write):
        ap.error("--no-compile is not valid with --check/--write")
    if args.point and args.write:
        ap.error("--point is not valid with --write (partial baseline)")

    points = None
    if args.point:
        from repro.audit.points import AUDIT_POINTS

        by_name = {pt.name: pt for pt in AUDIT_POINTS}
        unknown = sorted(set(args.point) - set(by_name))
        if unknown:
            ap.error(
                f"unknown audit point(s) {unknown}; "
                f"known: {sorted(by_name)}"
            )
        points = tuple(by_name[n] for n in dict.fromkeys(args.point))

    baseline = None
    if args.check:
        # load before the (slow) fresh build so a missing or malformed
        # baseline fails loudly and immediately, bench_compare-style
        try:
            baseline = load_manifest(args.baseline)
        except ManifestError as e:
            print(f"audit: {e}", file=sys.stderr)
            return 2

    fresh = build_manifest(points=points, compile_hlo=not args.no_compile)
    violations = manifest_violations(fresh)
    if args.out:
        write_manifest(args.out, fresh)

    if args.check:
        if points is not None:
            # compare only the selected points: a restricted run must not
            # report the *unselected* baseline points as deleted gates
            baseline = dict(baseline)
            baseline["points"] = {
                k: v
                for k, v in baseline.get("points", {}).items()
                if k in fresh["points"]
            }
        errs = violations + diff_manifests(fresh, baseline)
        for e in errs:
            print(f"audit: {e}", file=sys.stderr)
        if errs:
            return 1
        n = len(fresh["points"])
        print(f"audit OK: {n} points, all invariants hold, census matches")
        print(_summarise(fresh))
        return 0

    if args.write:
        if violations:
            for e in violations:
                print(f"audit: {e}", file=sys.stderr)
            print(
                "audit: refusing to write a baseline with violations",
                file=sys.stderr,
            )
            return 1
        write_manifest(args.baseline, fresh)
        print(f"wrote {args.baseline}: {len(fresh['points'])} points")
        print(_summarise(fresh))
        return 0

    for e in violations:
        print(f"audit: {e}", file=sys.stderr)
    print(f"audited {len(fresh['points'])} points:")
    print(_summarise(fresh))
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
