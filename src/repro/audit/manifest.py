"""Audit manifest: build, persist, and diff the per-point verdicts.

The manifest is the JSON artifact ``python -m repro.audit`` emits and CI
commits at ``benchmarks/baselines/audit.json``: one entry per audit
point with its rule verdicts, plan summary, op census, and compiled
donation/collective report.  ``--check`` rebuilds it fresh and fails on

* any rule violation in the fresh manifest (the invariants themselves —
  including ``overflow``, the numerical-safety class from
  ``repro.audit.ranges``);
* op-census drift against the baseline (a silent graph change — new
  primitives in a decode step, a vanished kernel dispatch);
* precision drift against the baseline (a layer's proved accumulator
  bound, minimal safe dtype, or worst-case error bound changed — the
  numbers are certificates, so any movement is a semantics change);
* a baseline point missing from the fresh run (a deleted gate).

Census and precision drift are *review* signals, not always bugs: a
legitimate change regenerates the baseline with ``--write`` (which
refuses to snapshot a manifest that violates the invariants).
"""
from __future__ import annotations

import json

# v2: adds the "overflow" rule class and the per-point "precision" report.
MANIFEST_VERSION = 2


class ManifestError(Exception):
    """A malformed or unusable manifest file."""


def build_manifest(points=None, compile_hlo: bool = True) -> dict:
    from repro.audit.points import AUDIT_POINTS, audit_point

    points = AUDIT_POINTS if points is None else points
    return {
        "version": MANIFEST_VERSION,
        "points": {pt.name: audit_point(pt, compile_hlo) for pt in points},
    }


def manifest_violations(manifest: dict) -> list[str]:
    """Flatten every rule violation in a manifest to human-readable lines."""
    out = []
    for name, entry in sorted(manifest.get("points", {}).items()):
        for rule, violations in sorted(entry.get("rules", {}).items()):
            for v in violations:
                out.append(
                    f"{name}: {rule} violated by {v['primitive']}: {v['detail']}"
                )
    return out


def diff_manifests(fresh: dict, baseline: dict) -> list[str]:
    """Census/precision/coverage drift against the committed baseline.

    Census drift compresses to ONE line per point/graph listing every
    drifted primitive as ``prim base->fresh (±d)`` — a reviewable signed
    summary instead of one raw line per primitive.
    """
    out = []
    base_points = baseline.get("points", {})
    fresh_points = fresh.get("points", {})
    for name in sorted(set(base_points) - set(fresh_points)):
        out.append(f"{name}: baseline point missing from fresh audit")
    for name in sorted(set(fresh_points) - set(base_points)):
        out.append(f"{name}: new audit point not in baseline (run --write)")
    for name in sorted(set(base_points) & set(fresh_points)):
        base_census = base_points[name].get("census", {})
        fresh_census = fresh_points[name].get("census", {})
        for graph in sorted(set(base_census) | set(fresh_census)):
            b = base_census.get(graph, {})
            f = fresh_census.get(graph, {})
            drifted = [
                f"{prim} {b.get(prim, 0)}->{f.get(prim, 0)} "
                f"({f.get(prim, 0) - b.get(prim, 0):+d})"
                for prim in sorted(set(b) | set(f))
                if b.get(prim, 0) != f.get(prim, 0)
            ]
            if drifted:
                out.append(
                    f"{name}/{graph}: op census drift: " + ", ".join(drifted)
                )
        bp = base_points[name].get("precision", {})
        fp = fresh_points[name].get("precision", {})
        for layer in sorted(set(bp) - set(fp)):
            out.append(f"{name}: precision entry {layer!r} missing from fresh audit")
        for layer in sorted(set(fp) - set(bp)):
            out.append(f"{name}: new precision entry {layer!r} not in baseline")
        for layer in sorted(set(bp) & set(fp)):
            bl, fl = bp[layer], fp[layer]
            changed = [
                f"{k} {bl.get(k)}->{fl.get(k)}"
                for k in sorted(set(bl) | set(fl))
                if bl.get(k) != fl.get(k)
            ]
            if changed:
                out.append(
                    f"{name}: precision drift at {layer!r}: " + ", ".join(changed)
                )
    return out


def load_manifest(path: str) -> dict:
    """Load a manifest, raising :class:`ManifestError` on anything off."""
    try:
        with open(path) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise ManifestError(
            f"manifest {path!r} not found — generate it with "
            f"`python -m repro.audit --write`"
        ) from None
    except json.JSONDecodeError as e:
        raise ManifestError(f"manifest {path!r} is not valid JSON: {e}") from None
    if not isinstance(manifest, dict) or "points" not in manifest:
        raise ManifestError(
            f"manifest {path!r} is malformed: expected an object with a "
            f"'points' key"
        )
    if manifest.get("version") != MANIFEST_VERSION:
        raise ManifestError(
            f"manifest {path!r} has version {manifest.get('version')!r}, "
            f"this tool expects {MANIFEST_VERSION}"
        )
    return manifest


def write_manifest(path: str, manifest: dict) -> None:
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
