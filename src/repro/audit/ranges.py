"""Per-plan range certificates: accumulator safety and worst-case error.

For every planned table format this module derives, in closed form, a sound
bound on the accumulator the LUT path builds — ``|acc| <= max_abs_acc`` —
plus a worst-case absolute output error decomposed into its two quantization
sources (narrow table storage and activation quantization).  The bounds are
*certificates*: :func:`repro.core.planner.plan_model` consults them to
reject knapsack candidates whose proved bound exceeds the kernel's
accumulator contract, the chosen plans carry them as ``acc_dtype`` /
``max_abs_acc`` (riding checkpoints like ``blocks``), the kernels assert
them at trace time (``repro.kernels.common.check_acc_contract``), and the
audit manifest gates CI on them (``overflow_violations`` /
``precision_report``).

Certificate math, per family (``w_max`` bounds per-weight magnitude,
``act_max`` per-activation magnitude; both default to 1.0 — normalised
units, scaled linearly by callers with real statistics):

**weight family** (tables built from weights, fp32 accumulate):
every gathered entry is ``sum_i coeff_i * W_i`` with the per-element
dequantised coefficient bounded by ``elem_max`` — fp16 ``full`` mode
65504 (the format max); fp16 bitplane modes ``32 * (2**(r*n) - 1)``
(per-plane slice max ``2**r - 1`` times plane scales summed,
``sigma_max = 2**5``; equals 65504 exactly at radix 1); fixed point the
format's ``max(|min_value|, max_value)`` (full) or ``(2**n - 1) * 2**-f``
(bitplane).  Hence ``max_abs_acc = padded_in * elem_max * w_max``; i8/i16
table storage inflates each gathered entry by at most ``maxabs / qmax``
(round-half + power-of-2 ceil scale), a uniform ``(1 + 1/qmax)`` factor.

**tl1 family** (activation-side 9-entry LUTs): on the int path the bound
is in CODE units — entries are ``±a0 ± a1`` with ``|a| <= qa =
2**(act_bits-1) - 1``, so ``entry_max = 2 * qa`` (must fit the int16
entry dtype) and ``max_abs_acc = 2 * qa * num_chunks`` accumulated in the
plan's ``acc_dtype``; the exact ``act_bits=None`` path is fp32 with
``max_abs_acc = 2 * act_max * num_chunks``.

Error bounds are absolute, on one output element, in value units:
``table_quant_err = exact_acc / qmax`` (narrow storage rounding),
``act_quant_err`` the activation rounding worst case (fp16: relative
``2**-11``; fixed point / TL1 absmax-int: half an LSB per element).
"""
from __future__ import annotations

import dataclasses
import math

from repro.audit.interp import Interval, dtype_interval, interval_eval
from repro.audit.rules import Violation
from repro.core.lut import LUTPlan
from repro.core.lut_tl1 import TL1Plan
from repro.core.quantize import Float16Format
from repro.kernels.common import ACC_CAPACITY, acc_capacity

_F16_MAX = 65504.0
_F16_SIGMA_MAX = 32.0  # 2**(30 - 25): max exponent field 30 for finite f16
_TABLE_QMAX = {"i8": 127.0, "i16": 32767.0}
_INT16_MAX = 32767.0


@dataclasses.dataclass(frozen=True)
class RangeCert:
    """The proved range/precision facts for one planned layer."""

    family: str  # "weight" | "tl1"
    integer: bool  # True when max_abs_acc counts integer CODE units
    max_abs_acc: float  # sound bound on |accumulator|
    min_acc_dtype: str  # smallest dtype in ACC_CAPACITY that holds it
    entry_max: float  # sound bound on |stored/built table entry|
    table_quant_err: float  # worst-case |error| from narrow table storage
    act_quant_err: float  # worst-case |error| from activation quantization

    @property
    def total_err(self) -> float:
        return self.table_quant_err + self.act_quant_err

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        for k in ("max_abs_acc", "entry_max", "table_quant_err", "act_quant_err"):
            d[k] = float(f"{d[k]:.8g}")  # stable across platforms in the manifest
        d["total_err"] = float(f"{self.total_err:.8g}")
        return d


def _min_acc_dtype(bound: float, integer: bool) -> str:
    if integer:
        for name in ("int16", "int32", "int64"):
            if bound <= ACC_CAPACITY[name]:
                return name
    return "float32"


def _weight_elem_max(plan: LUTPlan) -> float:
    """Max |dequantised value| one input element contributes through the
    tables, plane scales included (sound across all planes/modes)."""
    fmt = plan.fmt
    if isinstance(fmt, Float16Format):
        if plan.mode == "full":
            return _F16_MAX
        # bitplane / bitplane_shift: per plane a slice <= 2**r - 1 at plane
        # scale (2**r)**j, summed over planes, times sigma_max.  Radix 1 is
        # exactly the format max; wider radices are conservative (the slices
        # partition 11 mantissa bits, but per-plane maxima need not).
        r = fmt.mantissa_radix
        return _F16_SIGMA_MAX * float(2 ** (r * fmt.num_planes) - 1)
    if plan.mode == "full":
        return max(abs(fmt.min_value), abs(fmt.max_value))
    # fixed bitplane: every plane bit set, |plane_scales| summed.
    return float(2**fmt.total_bits - 1) * fmt.scale


def layer_range_cert(plan, *, w_max: float = 1.0, act_max: float = 1.0) -> RangeCert:
    """Closed-form :class:`RangeCert` for one plan (either family)."""
    if isinstance(plan, TL1Plan):
        if plan.act_bits is not None:
            qa = float(2 ** (int(plan.act_bits) - 1) - 1)
            entry_max = 2.0 * qa  # |±a0 ± a1| in code units
            max_abs_acc = entry_max * plan.num_chunks
            # per-element absmax rounding <= scale/2 = act_max/(2*qa),
            # through a |weight| <= w_max, summed over the input width.
            act_err = plan.in_features * w_max * act_max / (2.0 * qa)
            return RangeCert(
                family="tl1",
                integer=True,
                max_abs_acc=max_abs_acc,
                min_acc_dtype=_min_acc_dtype(max_abs_acc, integer=True),
                entry_max=entry_max,
                table_quant_err=0.0,  # ternary indices are stored exactly
                act_quant_err=act_err,
            )
        entry_max = 2.0 * act_max
        max_abs_acc = entry_max * plan.num_chunks
        return RangeCert(
            family="tl1",
            integer=False,
            max_abs_acc=max_abs_acc,
            min_acc_dtype="float32",
            entry_max=entry_max,
            table_quant_err=0.0,
            act_quant_err=0.0,  # the exact path quantizes nothing
        )
    if not isinstance(plan, LUTPlan):
        raise TypeError(f"expected LUTPlan or TL1Plan, got {type(plan)!r}")
    elem_max = _weight_elem_max(plan)
    exact_acc = plan.padded_in * elem_max * w_max
    if plan.table_format is not None:
        qmax = _TABLE_QMAX[plan.table_format]
        max_abs_acc = exact_acc * (1.0 + 1.0 / qmax)
        table_err = exact_acc / qmax
    else:
        max_abs_acc = exact_acc
        table_err = 0.0
    if isinstance(plan.fmt, Float16Format):
        # fp16 round-to-nearest: relative error <= 2**-11 per element.
        act_err = plan.padded_in * w_max * act_max * 2.0**-11
        entry_max = elem_max * plan.chunk_size * w_max
    else:
        act_err = plan.padded_in * w_max * plan.fmt.scale / 2.0
        entry_max = elem_max * plan.chunk_size * w_max
    return RangeCert(
        family="weight",
        integer=False,
        max_abs_acc=max_abs_acc,
        min_acc_dtype=_min_acc_dtype(max_abs_acc, integer=False),
        entry_max=entry_max,
        table_quant_err=table_err,
        act_quant_err=act_err,
    )


def precision_report(mplan, *, w_max: float = 1.0, act_max: float = 1.0) -> dict:
    """Per-layer certificate summary for the audit manifest (JSON-stable)."""
    out = {}
    for key in sorted(mplan.layers):
        plan = mplan.layers[key]
        cert = layer_range_cert(plan, w_max=w_max, act_max=act_max)
        out[key] = {"acc_dtype": plan.acc_dtype, **cert.to_json()}
    return out


def pallas_interval_model(mplan):
    """Closed-form interval model for opaque ``pallas_call`` interiors.

    The graph walk cannot see inside a kernel, but the kernels implement
    exactly the per-family contracts this module certifies, so their
    *outputs* are bounded by the certificates: integer results (TL1 int
    accumulators surfaced before the fp32 rescale) stay within the largest
    certified ``max_abs_acc`` of any integer-path plan; everything else
    falls back to the dtype range.
    """
    int_bound = 0.0
    for plan in mplan.layers.values():
        cert = layer_range_cert(plan)
        if cert.integer:
            int_bound = max(int_bound, cert.max_abs_acc, cert.entry_max)

    def model(eqn, ins):
        import numpy as np

        outs = []
        for v in eqn.outvars:
            d = np.dtype(v.aval.dtype)
            if d.kind == "i" and int_bound > 0:
                rng = dtype_interval(d)
                outs.append(
                    Interval(max(rng.lo, -int_bound), min(rng.hi, int_bound))
                )
            else:
                outs.append(dtype_interval(d))
        return outs

    return model


def overflow_violations(
    mplan,
    *,
    graphs=(),
    arg_intervals=None,
    pallas_model=None,
    w_max: float = 1.0,
    act_max: float = 1.0,
) -> list[Violation]:
    """The numerical-safety rule class: a clean pipeline returns ``[]``.

    Three plan-level checks per layer — the proved ``max_abs_acc`` fits the
    plan's declared ``acc_dtype``, TL1 int entries fit their int16 storage,
    and any bound stamped on the plan matches what the certificate proves
    now (a stale stamp means a plan rode a checkpoint across a semantics
    change) — plus one graph-level check: interval abstract interpretation
    over each named jaxpr in ``graphs`` (``(name, jaxpr)`` pairs, e.g. the
    decode and prefill steps) flags every signed-integer equation whose
    ideal result escapes its machine dtype.  ``pallas_model`` defaults to
    :func:`pallas_interval_model` over the same plan.
    """
    out: list[Violation] = []
    for key in sorted(mplan.layers):
        plan = mplan.layers[key]
        cert = layer_range_cert(plan, w_max=w_max, act_max=act_max)
        cap = acc_capacity(plan.acc_dtype)
        if cert.max_abs_acc > cap:
            out.append(
                Violation(
                    rule="overflow",
                    primitive="accumulate",
                    detail=(
                        f"{key}: proved |acc| bound {cert.max_abs_acc:.6g} "
                        f"exceeds acc_dtype={plan.acc_dtype!r} capacity "
                        f"{cap:.6g} (minimal safe dtype: "
                        f"{cert.min_acc_dtype})"
                    ),
                )
            )
        if cert.integer and cert.entry_max > _INT16_MAX:
            out.append(
                Violation(
                    rule="overflow",
                    primitive="table_entry",
                    detail=(
                        f"{key}: TL1 activation-LUT entry bound "
                        f"{cert.entry_max:.6g} exceeds the int16 entry "
                        f"dtype ({_INT16_MAX:.0f})"
                    ),
                )
            )
        stamped = getattr(plan, "max_abs_acc", None)
        if stamped is not None and not math.isclose(
            stamped, cert.max_abs_acc, rel_tol=1e-6
        ):
            out.append(
                Violation(
                    rule="overflow",
                    primitive="stale_bound",
                    detail=(
                        f"{key}: stamped max_abs_acc {stamped:.6g} != "
                        f"certified {cert.max_abs_acc:.6g} — restamp via "
                        f"plan_model"
                    ),
                )
            )
    model = pallas_model if pallas_model is not None else pallas_interval_model(mplan)
    for name, jaxpr in graphs:
        _, facts = interval_eval(jaxpr, arg_intervals, pallas_model=model)
        for f in facts:
            out.append(
                Violation(
                    rule="overflow",
                    primitive=f.primitive,
                    detail=f"{name}: {f.detail}",
                )
            )
    return out
