"""Static-analysis audit of the repo's structural contracts.

The paper's claim — inference "without the use of any multipliers" — is a
property of the *program*, not of any particular run.  This package
proves it (and the layout, donation, and plan-consistency contracts that
keep it cheap) by tracing the jitted serving steps for a committed matrix
of model configs x table families and checking rules on the closed jaxpr
and compiled HLO, with nothing executed:

* :func:`iter_eqns` / :func:`op_census` — the one recursive jaxpr walker
  (scan/while/cond/pjit/custom-vjp/remat; ``pallas_call`` stays opaque)
* :func:`multiplier_free_violations`, :func:`zero_copy_violations`,
  :func:`plan_consistency_violations`, :func:`donation_violations`,
  :func:`overflow_violations` — the rule classes (empty list == invariant
  holds)
* :func:`interval_eval` / :func:`layer_range_cert` /
  :func:`precision_report` — the range/overflow pass: interval abstract
  interpretation over the traced steps plus closed-form per-plan
  accumulator and error-bound certificates
* :data:`AUDIT_POINTS` / :func:`audit_point` / :func:`trace_point` — the
  audited matrix (one shared abstract trace per point)
* :func:`build_manifest` & friends — the JSON manifest behind
  ``python -m repro.audit --check`` (the CI gate) and ``--write``

See "Audited invariants" in ``src/repro/core/README.md`` for the rule
table.
"""
from repro.audit.compiled import (
    aliased_param_indices,
    compiled_report,
    donation_violations,
)
from repro.audit.interp import (
    INT_INPUT_BOUND,
    Interval,
    OverflowFact,
    default_arg_intervals,
    dtype_interval,
    interval_eval,
)
from repro.audit.manifest import (
    ManifestError,
    build_manifest,
    diff_manifests,
    load_manifest,
    manifest_violations,
    write_manifest,
)
from repro.audit.points import (
    AUDIT_POINTS,
    AuditPoint,
    audit_point,
    build_point,
    trace_point,
)
from repro.audit.ranges import (
    RangeCert,
    layer_range_cert,
    overflow_violations,
    pallas_interval_model,
    precision_report,
)
from repro.audit.rules import (
    Violation,
    multiplier_free_violations,
    plan_consistency_violations,
    planned_weight_shapes,
    table_leaf_shapes,
    zero_copy_violations,
)
from repro.audit.walker import OPAQUE_PRIMITIVES, as_eqns, iter_eqns, op_census

__all__ = [
    "AUDIT_POINTS",
    "AuditPoint",
    "INT_INPUT_BOUND",
    "Interval",
    "ManifestError",
    "OPAQUE_PRIMITIVES",
    "OverflowFact",
    "RangeCert",
    "Violation",
    "aliased_param_indices",
    "as_eqns",
    "audit_point",
    "build_manifest",
    "build_point",
    "compiled_report",
    "default_arg_intervals",
    "diff_manifests",
    "donation_violations",
    "dtype_interval",
    "interval_eval",
    "iter_eqns",
    "layer_range_cert",
    "load_manifest",
    "manifest_violations",
    "multiplier_free_violations",
    "op_census",
    "overflow_violations",
    "pallas_interval_model",
    "plan_consistency_violations",
    "planned_weight_shapes",
    "precision_report",
    "table_leaf_shapes",
    "trace_point",
    "write_manifest",
    "zero_copy_violations",
]
