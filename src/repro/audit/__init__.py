"""Static-analysis audit of the repo's structural contracts.

The paper's claim — inference "without the use of any multipliers" — is a
property of the *program*, not of any particular run.  This package
proves it (and the layout, donation, and plan-consistency contracts that
keep it cheap) by tracing the jitted serving steps for a committed matrix
of model configs x table families and checking rules on the closed jaxpr
and compiled HLO, with nothing executed:

* :func:`iter_eqns` / :func:`op_census` — the one recursive jaxpr walker
  (scan/while/cond/pjit/custom-vjp/remat; ``pallas_call`` stays opaque)
* :func:`multiplier_free_violations`, :func:`zero_copy_violations`,
  :func:`plan_consistency_violations`, :func:`donation_violations` — the
  rule classes (empty list == invariant holds)
* :data:`AUDIT_POINTS` / :func:`audit_point` — the audited matrix
* :func:`build_manifest` & friends — the JSON manifest behind
  ``python -m repro.audit --check`` (the CI gate) and ``--write``

See "Audited invariants" in ``src/repro/core/README.md`` for the rule
table.
"""
from repro.audit.compiled import (
    aliased_param_indices,
    compiled_report,
    donation_violations,
)
from repro.audit.manifest import (
    ManifestError,
    build_manifest,
    diff_manifests,
    load_manifest,
    manifest_violations,
    write_manifest,
)
from repro.audit.points import AUDIT_POINTS, AuditPoint, audit_point, build_point
from repro.audit.rules import (
    Violation,
    multiplier_free_violations,
    plan_consistency_violations,
    planned_weight_shapes,
    table_leaf_shapes,
    zero_copy_violations,
)
from repro.audit.walker import OPAQUE_PRIMITIVES, iter_eqns, op_census

__all__ = [
    "AUDIT_POINTS",
    "AuditPoint",
    "ManifestError",
    "OPAQUE_PRIMITIVES",
    "Violation",
    "aliased_param_indices",
    "audit_point",
    "build_manifest",
    "build_point",
    "compiled_report",
    "diff_manifests",
    "donation_violations",
    "iter_eqns",
    "load_manifest",
    "manifest_violations",
    "multiplier_free_violations",
    "op_census",
    "plan_consistency_violations",
    "planned_weight_shapes",
    "table_leaf_shapes",
    "write_manifest",
    "zero_copy_violations",
]
