"""The audited matrix: model config x table family x exec config points.

Every point is audited *fully abstractly* — ``abstract_params`` shapes
feed ``plan_model``, ``jax.eval_shape`` runs the converter over them, and
the serving steps are traced (and AOT-compiled for the donation pass)
over ``ShapeDtypeStruct`` trees.  No weights are initialised, no tables
are built, nothing executes; a point costs a trace plus one small CPU
compile, so the full matrix runs on every CI commit.

The committed points cover the three structural regimes the rules must
hold over: the attention weight-table path (pre-stacked ``LUTGroup``
decode), the TL1 activation-side family (packed ternary tables, per-step
activation LUTs), and the MoE expert path (ragged expert stacks, the
``ragged_dot`` temptation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.audit.compiled import compiled_report
from repro.audit.ranges import overflow_violations, precision_report
from repro.audit.rules import (
    multiplier_free_violations,
    plan_consistency_violations,
    planned_weight_shapes,
    table_leaf_shapes,
    zero_copy_violations,
)
from repro.audit.walker import as_eqns, op_census


@dataclasses.dataclass(frozen=True)
class AuditPoint:
    """One (model config, table family, exec config) cell of the matrix."""

    name: str
    arch: str
    families: tuple = ("weight",)
    convert_experts: bool = False
    tl1_act_bits: int | None = 8
    batch: int = 1
    cache_len: int = 16
    prefill_len: int = 4


AUDIT_POINTS = (
    # attention weight-table path: grouped fp16 tables, prestacked KV pair
    AuditPoint("granite_weight", "granite_8b", families=("weight",)),
    # TL1 activation-side family: packed ternary tables, exact act mode
    AuditPoint("granite_tl1", "granite_8b", families=("tl1",), tl1_act_bits=None),
    # MoE expert path: converted expert stacks through the ragged LUT route
    AuditPoint(
        "moe_weight_experts",
        "qwen2_moe_a2_7b",
        families=("weight",),
        convert_experts=True,
    ),
)


def build_point(pt: AuditPoint) -> dict:
    """Abstract artifacts for one point: plan, converted template, steps."""
    from repro.configs.base import get_config
    from repro.core.convert import convert_params
    from repro.core.planner import plan_model
    from repro.kernels.lut_affine.autotune import attach_tuned_blocks
    from repro.models.layers import Ctx, ExecCfg
    from repro.models.model import model_specs
    from repro.models.params import abstract_params
    from repro.serve import abstract_cache, make_decode_step, make_prefill_step

    cfg = get_config(pt.arch, reduced=True)
    aparams = abstract_params(model_specs(cfg))
    mplan = plan_model(
        aparams,
        float("inf"),
        max_chunk=1,
        families=pt.families,
        convert_experts=pt.convert_experts,
        tl1_act_bits=pt.tl1_act_bits,
    )
    # tuned blocks ride the plan so the VMEM-legality rule audits them too
    mplan = attach_tuned_blocks(mplan, pt.batch)
    template = jax.eval_shape(
        lambda p: convert_params(
            p,
            plan=mplan,
            table_dtype=jnp.float16,
            convert_experts=pt.convert_experts,
        )[0],
        aparams,
    )
    ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    cache = abstract_cache(cfg, pt.batch, pt.cache_len, ctx)
    return {
        "cfg": cfg,
        "mplan": mplan,
        "template": template,
        "ctx": ctx,
        "cache": cache,
        "decode": make_decode_step(ctx),
        "prefill": make_prefill_step(ctx),
        "decode_tokens": jax.ShapeDtypeStruct((pt.batch, 1), jnp.int32),
        "prefill_tokens": jax.ShapeDtypeStruct(
            (pt.batch, pt.prefill_len), jnp.int32
        ),
    }


def _vocab_dims(cfg) -> tuple[int, int]:
    pad = -(-cfg.vocab_size // cfg.vocab_pad_multiple) * cfg.vocab_pad_multiple
    return (cfg.vocab_size, pad)


def trace_point(pt: AuditPoint) -> dict:
    """Build one point's abstract trace ONCE, shared across all rule passes.

    Extends :func:`build_point` with the decode/prefill jaxprs and their
    pre-walked recursive equation lists (``decode_eqns`` / ``prefill_eqns``,
    consumable wherever a rule accepts ``walker.as_eqns`` input) — the trace
    is the expensive part of an audit, so ``--point`` single-point runs and
    multi-rule full runs both pay it exactly once.
    """
    art = build_point(pt)
    art["decode_jaxpr"] = jax.make_jaxpr(art["decode"])(
        art["template"], art["cache"], art["decode_tokens"]
    )
    art["prefill_jaxpr"] = jax.make_jaxpr(art["prefill"])(
        art["template"], {"tokens": art["prefill_tokens"]}, art["cache"]
    )
    art["decode_eqns"] = as_eqns(art["decode_jaxpr"])
    art["prefill_eqns"] = as_eqns(art["prefill_jaxpr"])
    return art


def audit_point(
    pt: AuditPoint, compile_hlo: bool = True, trace: dict | None = None
) -> dict:
    """Run every rule class over one point; return its manifest entry.

    ``compile_hlo=False`` skips the AOT donation/collective pass (the only
    part that invokes XLA) for fast jaxpr-only audits.  ``trace`` reuses a
    :func:`trace_point` result instead of re-tracing.
    """
    art = trace if trace is not None else trace_point(pt)
    mplan, template, cache = art["mplan"], art["template"], art["cache"]
    decode_jaxpr, prefill_jaxpr = art["decode_jaxpr"], art["prefill_jaxpr"]

    weight_shapes = planned_weight_shapes(mplan)
    table_shapes = table_leaf_shapes(template)
    exempt = _vocab_dims(art["cfg"])
    rules = {
        "multiplier_free": [
            v.to_json()
            for eqns in (art["decode_eqns"], art["prefill_eqns"])
            for v in multiplier_free_violations(
                eqns,
                weight_shapes=weight_shapes,
                table_shapes=table_shapes,
                exempt_dims=exempt,
            )
        ],
        # the zero-copy contract is about the per-token step; prefill may
        # legitimately lay out its prompt-length activations
        "zero_copy": [
            v.to_json()
            for v in zero_copy_violations(
                art["decode_eqns"], table_shapes=table_shapes
            )
        ],
        "plan_consistency": [
            v.to_json()
            for v in plan_consistency_violations(mplan, template, batch=pt.batch)
        ],
        # numerical safety: closed-form per-plan certificates + interval
        # abstract interpretation over both traced steps
        "overflow": [
            v.to_json()
            for v in overflow_violations(
                mplan,
                graphs=(("decode", decode_jaxpr), ("prefill", prefill_jaxpr)),
            )
        ],
    }
    entry = {
        "plan": {
            "layers": len(mplan.layers),
            "groups": len(mplan.groups),
            "families": list(mplan.families),
            "total_lut_bytes": mplan.total_lut_bytes,
        },
        "rules": rules,
        "census": {
            "decode": op_census(art["decode_eqns"]),
            "prefill": op_census(art["prefill_eqns"]),
        },
        "precision": precision_report(mplan),
    }
    if compile_hlo:
        n_params = len(jax.tree_util.tree_leaves(template))
        n_cache = len(jax.tree_util.tree_leaves(cache))
        # same donation signature serve.generate jits its steps with
        decode_hlo = (
            jax.jit(art["decode"], donate_argnums=(1,))
            .lower(template, cache, art["decode_tokens"])
            .compile()
            .as_text()
        )
        prefill_hlo = (
            jax.jit(art["prefill"], donate_argnums=(2,))
            .lower(template, {"tokens": art["prefill_tokens"]}, cache)
            .compile()
            .as_text()
        )
        compiled = {
            # flat param order: params ++ (prefill: tokens) ++ cache leaves
            "decode": compiled_report(
                decode_hlo, range(n_params, n_params + n_cache)
            ),
            "prefill": compiled_report(
                prefill_hlo, range(n_params + 1, n_params + 1 + n_cache)
            ),
        }
        entry["rules"]["donation"] = [
            v for g in compiled.values() for v in g["donation"]
        ]
        entry["compiled"] = {
            g: {k: v for k, v in rep.items() if k != "donation"}
            for g, rep in compiled.items()
        }
    return entry
