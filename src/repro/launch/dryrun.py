# ruff: noqa: E402 -- BackendConfig.apply() must run before any jax import
import os

from repro.launch.backend import BackendConfig

BackendConfig(host_device_count=512).apply()
# ^^ MUST precede any jax-importing module: jax locks the device count at
# first init.  Only the dry-run sees 512 placeholder devices.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes, record memory/cost/collective
analysis for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --all [--resume]
  PYTHONPATH=src python -m repro.launch.dryrun \\
      --arch granite_8b --shape train_4k --mesh single

``--all`` drives one subprocess per cell (isolation: a pathological cell
cannot poison the rest) and appends records to results/dryrun.json.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_NAMES, get_config
from repro.dist.sharding import ShardCtx
from repro.launch import hlo_analysis as H
from repro.launch.inputs import (
    SHAPES,
    cell_is_runnable,
    decode_input_specs,
    prefill_input_specs,
    shape_case,
    train_input_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import abstract_params
from repro.serve import abstract_cache, make_decode_step, make_prefill_step
from repro.train.trainer import TrainConfig, make_train_step

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")


def _sharded_inputs(specs: dict, ctx: Ctx):
    out = {}
    for k, s in specs.items():
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        out[k] = jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=ctx.shard.sharding(axes, s.shape)
        )
    return out


def _abstract_state(cfg, ctx: Ctx, dtype):
    params = abstract_params(
        model_specs(cfg), default_dtype=dtype, sharding_fn=ctx.shard.param_sharding
    )
    return params


def _abstract_opt(params):
    mom = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=s.sharding),
        params,
    )
    return {
        "m": mom,
        "v": jax.tree.map(lambda s: s, mom),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def abstract_lut_params(cfg, ctx: Ctx, chunk_size: int = 1,
                        fsdp_tables: bool = False):
    """Shape/sharding stand-ins for a TableNet-converted parameter tree:
    eval_shape through the conversion pass, tables sharded on their output
    dim like the weights they replace.  Works for both per-projection
    ``LUTLinear`` and pre-stacked ``LUTGroup`` leaves: either way the
    ``tables`` leaf ends in ``(..., k, entries, p)`` with ``p`` last and
    ``k`` third-from-last, which is all the sharding rules key on."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.convert import convert_params

    std = _abstract_state(cfg, ctx, jnp.bfloat16)
    shapes = jax.eval_shape(
        lambda p: convert_params(p, chunk_size=chunk_size, table_dtype=jnp.bfloat16)[0],
        std,
    )

    def shard(path, leaf):
        # dict levels carry DictKey (.key); LUTLinear/LUTGroup children
        # carry GetAttrKey (.name)
        name = getattr(path[-1], "key", None) or getattr(path[-1], "name", None)
        name = name if name is not None else str(path[-1])
        if name == "tables":
            p_out = leaf.shape[-1]
            n_model = ctx.shard.axis_size("model")
            tp = "model" if n_model and p_out % n_model == 0 else None
            axes = [None] * (leaf.ndim - 1) + [tp]
            if fsdp_tables:  # shard the chunk dim over data (ZeRO-3 tables)
                k = leaf.shape[-3]
                if k % max(ctx.shard.axis_size("data"), 1) == 0:
                    axes[-3] = "data"
            spec = P(*axes)
            return jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype, sharding=NamedSharding(ctx.shard.mesh, spec)
            )
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(ctx.shard.mesh, P(*([None] * leaf.ndim))),
        )

    # reuse original shardings where paths coincide (embed, norms, biases...)
    std_flat = dict(
        (jax.tree_util.keystr(p), l)
        for p, l in jax.tree_util.tree_flatten_with_path(std)[0]
    )

    def build(path, leaf):
        key = jax.tree_util.keystr(path)
        if key in std_flat and std_flat[key].shape == leaf.shape:
            return std_flat[key]
        return shard(path, leaf)

    return jax.tree_util.tree_map_with_path(build, shapes)


def lower_cell(
    arch: str,
    shape: str,
    mesh_kind: str,
    exec_overrides: dict | None = None,
    cfg_overrides: dict | None = None,
    case_overrides: dict | None = None,
    rules: str = "default",
    params_mode: str = "standard",
):
    """Returns (lowered, compiled, ctx, case, cfg)."""
    from repro.dist.sharding import RULE_SETS

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    case = shape_case(shape)
    if case_overrides:
        case = dataclasses.replace(case, **case_overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ex_kw = dict(remat="full" if case.kind == "train" else "none")
    ex_kw.update(exec_overrides or {})
    microbatches = ex_kw.pop("microbatches", 1)  # TrainConfig knob, not ExecCfg
    lut_fsdp = ex_kw.pop("lut_fsdp", False)
    ctx = Ctx(cfg, shard=ShardCtx(mesh, RULE_SETS[rules]), ex=ExecCfg(**ex_kw))

    if case.kind == "train":
        params = _abstract_state(cfg, ctx, jnp.float32)
        opt = _abstract_opt(params)
        batch = _sharded_inputs(train_input_specs(cfg, case), ctx)
        tc = TrainConfig(microbatches=microbatches)
        step = make_train_step(ctx, tc)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt, batch)
    elif case.kind == "prefill":
        params = (abstract_lut_params(cfg, ctx, fsdp_tables=lut_fsdp)
                  if params_mode == "lut"
                  else _abstract_state(cfg, ctx, jnp.bfloat16))
        cache = abstract_cache(cfg, case.global_batch, case.seq_len, ctx)
        inputs = _sharded_inputs(prefill_input_specs(cfg, case), ctx)
        ctx = dataclasses.replace(ctx, ex=dataclasses.replace(ctx.ex, logits="last"))
        step = make_prefill_step(ctx)
        lowered = jax.jit(step, donate_argnums=(2,)).lower(params, inputs, cache)
    else:  # decode
        params = (abstract_lut_params(cfg, ctx, fsdp_tables=lut_fsdp)
                  if params_mode == "lut"
                  else _abstract_state(cfg, ctx, jnp.bfloat16))
        cache = abstract_cache(cfg, case.global_batch, case.seq_len, ctx)
        tokens = _sharded_inputs(decode_input_specs(cfg, case), ctx)["tokens"]
        step = make_decode_step(ctx)
        lowered = jax.jit(step, donate_argnums=(1,)).lower(params, cache, tokens)

    compiled = lowered.compile()
    return lowered, compiled, ctx, case, cfg



def _raw_costs(compiled) -> "np.ndarray":
    """[flops, hbm_bytes, link_bytes] of one compiled per-device module."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    if not hbm:
        ma = compiled.memory_analysis()
        hbm = sum(
            getattr(ma, k, 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")
        )
    link = H.collective_stats(compiled.as_text()).link_bytes
    return np.array([flops, hbm, link], dtype=np.float64)


def _probe(arch, shape, mesh_kind, exec_overrides, cfg_ov, case_ov=None,
           rules="default", params_mode="standard"):
    ex = dict(exec_overrides or {})
    ex["inner_unroll"] = True  # chunk-scan bodies must appear nc times
    _, compiled, _, _, _ = lower_cell(
        arch, shape, mesh_kind, ex, cfg_overrides=cfg_ov, case_overrides=case_ov,
        rules=rules, params_mode=params_mode,
    )
    return _raw_costs(compiled)


def corrected_costs(arch, shape, mesh_kind, exec_overrides=None,
                    rules="default", params_mode="standard"):
    """XLA cost analysis counts lax.scan bodies ONCE — reconstruct true
    totals by depth-differencing probe compiles (DESIGN.md §6)."""
    cfg = get_config(arch)
    L = cfg.num_layers
    probes = {}

    def P(name, cfg_ov, case_ov=None):
        probes[name] = _probe(arch, shape, mesh_kind, exec_overrides, cfg_ov,
                              case_ov, rules=rules, params_mode=params_mode)
        return probes[name]

    if cfg.family == "encdec":
        f11 = P("e1d1", {"encoder_layers": 1, "num_layers": 1})
        f21 = P("e2d1", {"encoder_layers": 2, "num_layers": 1})
        f12 = P("e1d2", {"encoder_layers": 1, "num_layers": 2})
        total = f11 + (cfg.encoder_layers - 1) * (f21 - f11) + (L - 1) * (f12 - f11)
    elif cfg.family == "hybrid":
        from repro.models.hybrid import segments

        f1, f2 = P("d1", {"num_layers": 1}), P("d2", {"num_layers": 2})
        g = cfg.shared_attn_every
        f6, f7 = P("d6", {"num_layers": g}), P("d7", {"num_layers": g + 1})
        mamba = f2 - f1
        shared = f7 - f6 - mamba
        n_shared = len(segments(cfg)) - 1
        total = f1 + (L - 1) * mamba + n_shared * shared
    elif cfg.family == "ssm" and shape_case(shape).kind != "decode":
        # rwkv: the heavy intra-chunk math lives INSIDE a chunk scan; the
        # depth probes unroll it (inner_unroll) — exact but compile-heavy,
        # so long sequences probe at S=4096 and scale (every rwkv cost is
        # linear in S at fixed chunk size; same chunk picked for both)
        case = shape_case(shape)
        S = case.seq_len
        case_ov = {"seq_len": 4096} if S > 4096 else None
        scale = S / 4096 if S > 4096 else 1.0
        f1 = P("d1", {"num_layers": 1}, case_ov)
        f2 = P("d2", {"num_layers": 2}, case_ov)
        total = (f1 + (L - 1) * (f2 - f1)) * scale
    else:
        f1, f2 = P("d1", {"num_layers": 1}), P("d2", {"num_layers": 2})
        total = f1 + (L - 1) * (f2 - f1)
    total = np.maximum(total, 0.0)
    return total, {k: v.tolist() for k, v in probes.items()}


def analyse(compiled, cfg, case, mesh_kind: str, corrected=None, probes=None) -> dict:
    chips = 512 if mesh_kind == "multi" else 256
    raw = _raw_costs(compiled)
    flops, hbm_bytes, link_bytes = (corrected if corrected is not None else raw)
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_mib": getattr(ma, "argument_size_in_bytes", 0) / 2**20,
            "output_mib": getattr(ma, "output_size_in_bytes", 0) / 2**20,
            "temp_mib": getattr(ma, "temp_size_in_bytes", 0) / 2**20,
            "alias_mib": getattr(ma, "alias_size_in_bytes", 0) / 2**20,
        }
    coll = H.collective_stats(compiled.as_text())
    terms = H.roofline_terms(flops, hbm_bytes, link_bytes)
    mflops = H.model_flops(cfg, case)
    useful = mflops / chips / flops if flops else 0.0
    return {
        "chips": chips,
        "flops_per_device": float(flops),
        "hbm_bytes_per_device": float(hbm_bytes),
        "link_bytes_per_device": float(link_bytes),
        "raw_uncorrected": raw.tolist(),
        "probes": probes or {},
        "collectives": coll.to_dict(),
        "memory": mem,
        "terms": terms,
        "model_flops_total": mflops,
        "useful_flops_ratio": useful,
    }


def run_cell(arch: str, shape: str, mesh_kind: str, exec_overrides=None,
             rules: str = "default", params_mode: str = "standard",
             tag: str = "") -> dict:
    cfg = get_config(arch)
    case = shape_case(shape)
    ok, reason = cell_is_runnable(cfg, case)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "kind": case.kind}
    if tag:
        rec["tag"] = tag
    if rules != "default":
        rec["rules"] = rules
    if params_mode != "standard":
        rec["params_mode"] = params_mode
    if not ok:
        return dict(rec, status="skipped", reason=reason)
    t0 = time.time()
    try:
        lowered, compiled, ctx, case, cfg = lower_cell(
            arch, shape, mesh_kind, exec_overrides, rules=rules,
            params_mode=params_mode,
        )
        corrected, probes = corrected_costs(
            arch, shape, mesh_kind, exec_overrides, rules=rules,
            params_mode=params_mode,
        )
    except Exception as e:
        return dict(
            rec, status="failed", error=f"{type(e).__name__}: {e}",
            trace=traceback.format_exc()[-2000:],
        )
    rec.update(analyse(compiled, cfg, case, mesh_kind, corrected, probes))
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["status"] = "ok"
    return rec


def _load(path: str) -> list:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return []


def _driver(args):
    """Spawn one subprocess per cell; append results incrementally."""
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    done = {
        (r["arch"], r["shape"], r["mesh"])
        for r in _load(args.out)
        if r.get("status") in ("ok", "skipped")
    }
    meshes = args.meshes.split(",")
    cells = [
        (a, s.name, m)
        for a in (args.archs.split(",") if args.archs else ARCH_NAMES)
        for s in SHAPES
        for m in meshes
    ]
    for arch, shape, mesh_kind in cells:
        if args.resume and (arch, shape, mesh_kind) in done:
            print(f"[skip-done] {arch} {shape} {mesh_kind}")
            continue
        print(f"[cell] {arch} {shape} {mesh_kind} ...", flush=True)
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
            "--out", args.out, "--append",
        ]
        env = dict(os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=args.timeout)
        if r.returncode != 0:
            results = _load(args.out)
            results.append({
                "arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "crashed", "error": (r.stderr or "")[-2000:],
            })
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
            err = (r.stderr or "").strip().splitlines()[-1] if r.stderr else "?"
            print(f"  CRASHED: {err}")
        else:
            print("  " + (r.stdout.strip().splitlines()[-1] if r.stdout else "ok"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", help="comma list for --all")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--exec", default=None,
                    help='JSON ExecCfg overrides, e.g. {"remat":"dots"}')
    ap.add_argument("--rules", default="default", choices=["default", "no_fsdp"])
    ap.add_argument("--params", default="standard", choices=["standard", "lut"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.all:
        _driver(args)
        return

    overrides = json.loads(args.exec) if args.exec else None
    rec = run_cell(args.arch, args.shape, args.mesh, overrides,
                   rules=args.rules, params_mode=args.params, tag=args.tag)
    if args.append:
        results = [
            r for r in _load(args.out)
            if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                    and r["mesh"] == rec["mesh"]
                    and r.get("tag", "") == rec.get("tag", ""))
        ]
        results.append(rec)
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    if rec["status"] == "ok":
        t = rec["terms"]
        print(
            f"{rec['arch']} {rec['shape']} {rec['mesh']}: OK "
            f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
            f"coll={t['collective_s']:.4f}s dom={t['dominant']} "
            f"frac={t['roofline_fraction']:.3f} compile={rec['compile_s']}s"
        )
    else:
        print(f"{rec['arch']} {rec['shape']} {rec['mesh']}: {rec['status']} "
              f"{rec.get('reason', rec.get('error', ''))}")
        if rec["status"] == "failed":
            print(rec.get("trace", ""), file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
