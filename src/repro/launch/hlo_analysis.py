"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``compiled.as_text()`` is the *partitioned* (per-device) module, so all
sizes extracted here are per-chip.  Collective traffic uses the standard
ring-algorithm model over the op's replica-group size N:

  all-reduce       2 (N-1)/N * payload      (reduce-scatter + all-gather)
  all-gather       (N-1)/N * result bytes   (result = full gathered tensor)
  reduce-scatter   (N-1)/N * operand bytes  (operand = N * result)
  all-to-all       (N-1)/N * payload
  collective-permute  payload               (one hop per chip)

Hardware model (TPU v5e-class, per the assignment): 197 bf16 TFLOP/s,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z]+[0-9a-z]*)\[([\d,]*)\]")
# "%name = TYPE op-name(" where TYPE may be a tuple
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [G,N] = G groups of N
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    by_op: dict
    result_bytes: int  # raw sum of collective result sizes
    link_bytes: float  # ring-model bytes through each chip's links

    def to_dict(self):
        return {
            "by_op": self.by_op,
            "result_bytes": self.result_bytes,
            "link_bytes": self.link_bytes,
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    by_op: dict[str, dict[str, float]] = {}
    total_result = 0
    total_link = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":  # -start carries the payload; skip the done
            continue
        type_str, op = m.group(1), m.group(2)
        payload = _type_bytes(type_str)
        n = max(_group_size(line), 1)
        ring = (n - 1) / n if n > 1 else 0.0
        if op == "all-reduce":
            link = 2 * ring * payload
        elif op == "all-gather":
            link = ring * payload  # result is the gathered tensor
        elif op == "reduce-scatter":
            link = ring * payload * n  # operand = N * result
        elif op == "all-to-all":
            link = ring * payload
        else:  # collective-permute
            link = float(payload)
        rec = by_op.setdefault(op, {"count": 0, "result_bytes": 0, "link_bytes": 0.0})
        rec["count"] += 1
        rec["result_bytes"] += payload
        rec["link_bytes"] += link
        total_result += payload
        total_link += link
    return CollectiveStats(by_op, total_result, total_link)


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    link_bytes_per_device: float,
) -> dict:
    compute = flops_per_device / PEAK_FLOPS
    memory = hbm_bytes_per_device / HBM_BW
    collective = link_bytes_per_device / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dominant.replace("_s", "")
    # roofline fraction: how much of the binding resource's time is the
    # compute we actually want (1.0 == perfectly compute-bound at peak)
    terms["roofline_fraction"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, case) -> float:
    """MODEL_FLOPS: 6*N*D train / 2*N*D inference (N = active params)."""
    n_active = cfg.active_param_count()
    if case.kind == "train":
        tokens = case.global_batch * case.seq_len
        return 6.0 * n_active * tokens
    if case.kind == "prefill":
        tokens = case.global_batch * case.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * case.global_batch  # decode: one token per seq
