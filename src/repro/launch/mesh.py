"""Production mesh construction (spec-mandated shapes).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (device count is locked at first jax init, and the
dry-run must set XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests, local runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    assert data * model <= n, (data, model, n)
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))
