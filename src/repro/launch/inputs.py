"""Model input construction: ShapeDtypeStruct stand-ins for the dry-run and
real arrays for smoke tests / examples.

Per the assignment: [vlm]/[audio] frontends are stubs — ``embeds`` /
``enc_embeds`` are precomputed patch/frame embeddings.  Whisper pairs an
encoder frame sequence of the same nominal seq_len with the decoder tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = (
    ShapeCase("train_4k", 4096, 256, "train"),
    ShapeCase("prefill_32k", 32768, 32, "prefill"),
    ShapeCase("decode_32k", 32768, 128, "decode"),
    ShapeCase("long_500k", 524288, 1, "decode"),
)


def shape_case(name: str) -> ShapeCase:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_is_runnable(cfg: ModelConfig, case: ShapeCase) -> tuple[bool, str]:
    """The assignment's skip rules (recorded, not silently dropped)."""
    if case.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full quadratic attention at 512k context (per spec: skip)"
    return True, ""


def train_input_specs(cfg: ModelConfig, case: ShapeCase, dtype=jnp.bfloat16) -> dict:
    B, S = case.global_batch, case.seq_len
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    inputs: dict[str, Any] = {}
    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        inputs["embeds"] = jax.ShapeDtypeStruct((B, n_img, cfg.d_model), dtype)
        inputs["tokens"] = tok((B, S - n_img))
        inputs["labels"] = tok((B, S))
    elif cfg.family == "encdec":
        inputs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
        inputs["tokens"] = tok((B, S))
        inputs["labels"] = tok((B, S))
    else:
        inputs["tokens"] = tok((B, S))
        inputs["labels"] = tok((B, S))
    return inputs


def prefill_input_specs(cfg: ModelConfig, case: ShapeCase, dtype=jnp.bfloat16) -> dict:
    B, S = case.global_batch, case.seq_len
    inputs: dict[str, Any] = {}
    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        inputs["embeds"] = jax.ShapeDtypeStruct((B, n_img, cfg.d_model), dtype)
        inputs["tokens"] = jax.ShapeDtypeStruct((B, S - n_img), jnp.int32)
    elif cfg.family == "encdec":
        inputs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
        inputs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        inputs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return inputs


def decode_input_specs(cfg: ModelConfig, case: ShapeCase) -> dict:
    return {"tokens": jax.ShapeDtypeStruct((case.global_batch, 1), jnp.int32)}


def materialize(specs: dict, key: jax.Array, vocab: int) -> dict:
    """Real random arrays matching a spec dict (smoke tests, examples)."""
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, vocab, dtype=s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return out
