"""Process-level backend configuration, applied BEFORE JAX initialises.

JAX reads ``XLA_FLAGS`` / ``JAX_PLATFORMS`` once, when the first backend is
created, and locks them for the life of the process.  Every entry point that
needs a non-default backend setup (the dry-run's 512 fake host devices, a
bench pinned to CPU, an experiment flipping an XLA knob) therefore has to
mutate ``os.environ`` before anything imports-and-uses jax — which each
script used to do ad hoc at the top of the file.

:class:`BackendConfig` centralises that dance: declare the platform, host
device count and extra XLA flags, then ``apply()`` exactly once, first thing
in ``main``.  ``apply`` refuses to run after JAX has initialised (a silent
no-op there is the worst failure mode: flags that look set but never reached
the compiler) and merges with any flags already in the environment — the
caller's CI matrix can still inject ``XLA_FLAGS`` from outside.

CLI entry points get the standard trio of arguments via :func:`add_args` /
:func:`from_args`::

    ap = argparse.ArgumentParser()
    backend.add_args(ap)
    args = ap.parse_args()
    backend.from_args(args).apply()
    import jax  # first jax use AFTER apply()
"""
from __future__ import annotations

import dataclasses
import os
import sys
from typing import Mapping, MutableMapping


def jax_initialised() -> bool:
    """Whether this process already created a JAX backend (flags locked)."""
    xb = sys.modules.get("jax._src.xla_bridge")
    return bool(getattr(xb, "_backends", None))


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Declarative XLA/JAX process setup.

    ``platform`` pins ``JAX_PLATFORMS`` ("cpu", "tpu", "gpu", or a
    comma-list of fallbacks); ``host_device_count`` is the dry-run's
    ``--xla_force_host_platform_device_count`` (fake CPU devices for mesh
    experiments); ``xla_flags`` are raw ``--xla_*`` strings appended last,
    so they win over both defaults and the inherited environment.
    """

    platform: str | None = None
    host_device_count: int | None = None
    xla_flags: tuple[str, ...] = ()

    def merged_xla_flags(self, env: Mapping[str, str]) -> str:
        """Inherited ``XLA_FLAGS`` + this config's flags (ours last)."""
        parts = [f for f in env.get("XLA_FLAGS", "").split() if f]
        if self.host_device_count is not None:
            parts = [
                f
                for f in parts
                if not f.startswith("--xla_force_host_platform_device_count=")
            ]
            parts.append(
                f"--xla_force_host_platform_device_count={self.host_device_count}"
            )
        parts.extend(self.xla_flags)
        return " ".join(parts)

    def apply(self, env: MutableMapping[str, str] | None = None) -> None:
        """Write the config into the process environment (idempotent).

        Raises if a JAX backend already exists: flags set now would be
        silently ignored, which is strictly worse than failing loudly.
        """
        if jax_initialised():
            raise RuntimeError(
                "BackendConfig.apply() called after JAX initialised a "
                "backend; XLA_FLAGS/JAX_PLATFORMS are already locked. "
                "Apply the config before the first jax use."
            )
        env = os.environ if env is None else env
        flags = self.merged_xla_flags(env)
        if flags:
            env["XLA_FLAGS"] = flags
        if self.platform is not None:
            env["JAX_PLATFORMS"] = self.platform


def add_args(ap) -> None:
    """Attach the standard backend CLI arguments to ``ap``."""
    ap.add_argument(
        "--platform",
        default=None,
        help="pin JAX_PLATFORMS (cpu | tpu | gpu | comma-list of fallbacks)",
    )
    ap.add_argument(
        "--xla-flag",
        action="append",
        default=[],
        metavar="--xla_...=v",
        help="extra XLA flag (repeatable); appended after inherited XLA_FLAGS",
    )
    ap.add_argument(
        "--host-device-count",
        type=int,
        default=None,
        help="fake host-platform device count (mesh dry-runs)",
    )


def from_args(args) -> BackendConfig:
    return BackendConfig(
        platform=args.platform,
        host_device_count=args.host_device_count,
        xla_flags=tuple(args.xla_flag),
    )
