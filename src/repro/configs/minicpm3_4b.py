"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: MLA latent-KV attention."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,  # assignment annotation; MLA supersedes (DESIGN.md §5)
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,  # pads to 73472 for 16-way vocab TP
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        vocab_pad_multiple=16,
    )
