"""Whisper-base [arXiv:2212.04356]: enc-dec; conv/mel frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,  # decoder
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    pos="sinusoidal",
    attn_bias=True,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, vocab_pad_multiple=16,
    )
