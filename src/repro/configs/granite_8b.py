"""Granite-8B-Code [arXiv:2405.04324]: llama-arch, tied embeddings."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    tie_embeddings=True,
    rope_theta=10_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
    )
