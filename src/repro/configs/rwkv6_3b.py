"""RWKV-6 "Finch" 3B [arXiv:2404.05892]: attention-free, data-dependent decay."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # d_model / rwkv_head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attention="none",
    rwkv_head_dim=64,
    decay_lora_rank=64,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=224, vocab_size=512, rwkv_head_dim=16, decay_lora_rank=8,
        vocab_pad_multiple=16,
    )
