"""Model configuration schema + registry.

One file per assigned architecture lives next to this module; each exports
``CONFIG`` (the exact published configuration) and ``reduced()`` (a tiny
same-family variant for CPU smoke tests).  ``get_config(name)`` resolves
either.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention ---
    attention: str = "gqa"  # gqa | mla | none
    attn_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    pos: str = "rope"  # rope | sinusoidal | none
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0  # qwen2-moe style always-on experts
    router_aux_coef: float = 0.001
    # --- MLA (minicpm3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    mamba_head_dim: int = 64
    mamba_expand: int = 2
    conv_kernel: int = 4
    shared_attn_every: int = 0  # zamba2: shared attn+mlp block cadence
    # --- RWKV ---
    rwkv_head_dim: int = 64
    decay_lora_rank: int = 64
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    # --- VLM ---
    num_image_tokens: int = 0
    # --- embedding / misc ---
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256  # Megatron-style padding => TP-divisible
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ---------------------------------------------------------------- derived
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def q_dim(self) -> int:
        if self.attention == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # mamba2
        return self.mamba_expand * self.d_model

    @property
    def mamba_heads(self) -> int:
        return self.d_inner // self.mamba_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve a 512k-token context (long_500k shape)?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode; encoder-only would flip this

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        from repro.models.params import count_params
        from repro.models.model import model_specs

        return count_params(model_specs(self))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        total = self.param_count()
        per_expert = 3 * self.d_model * self.moe_d_ff
        inactive = (self.num_experts - self.num_experts_per_tok) * per_expert
        return total - self.num_layers * inactive


ARCH_NAMES = [
    "qwen2_moe_a2_7b",
    "mixtral_8x7b",
    "zamba2_1_2b",
    "minitron_4b",
    "granite_8b",
    "phi3_medium_14b",
    "minicpm3_4b",
    "llava_next_mistral_7b",
    "whisper_base",
    "rwkv6_3b",
]


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    key = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.reduced() if reduced else mod.CONFIG


def list_configs() -> list[str]:
    return list(ARCH_NAMES)
