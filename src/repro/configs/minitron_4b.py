"""Minitron-4B [arXiv:2407.14679]: width/depth-pruned Nemotron-4."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,  # 24 % 16 != 0 -> q-seq fallback TP (DESIGN.md §4)
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    act="relu2",  # nemotron squared-ReLU 2-matrix MLP
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=6, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
    )
