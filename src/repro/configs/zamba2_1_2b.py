"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + shared attention block."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,  # mamba2 layers; shared attn+mlp every 6
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    mamba_head_dim=64,
    mamba_expand=2,
    conv_kernel=4,
    shared_attn_every=6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, ssm_state=16, mamba_head_dim=16,
        shared_attn_every=2, vocab_pad_multiple=16,
    )
