"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The anyres vision tower is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (num_image_tokens x d_model).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_image_tokens=576,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, num_image_tokens=8, vocab_pad_multiple=16,
    )
