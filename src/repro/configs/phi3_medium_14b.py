"""Phi-3-medium-14B [arXiv:2404.14219]: RoPE + SwiGLU + GQA (kv=10)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,  # 40 % 16 != 0 -> q-seq fallback TP
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=80, num_heads=5, num_kv_heads=5, head_dim=16,
        d_ff=160, vocab_size=512, vocab_pad_multiple=16,
    )
