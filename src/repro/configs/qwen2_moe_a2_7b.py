"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 4 shared + 60 routed top-4."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,  # shared-expert dense branch width (4 x 1408)
    vocab_size=151936,
    attn_bias=True,
    num_experts=60,
    num_experts_per_tok=4,
    moe_d_ff=1408,
    num_shared_experts=4,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, num_experts=8, num_experts_per_tok=4, moe_d_ff=32,
        num_shared_experts=2, vocab_pad_multiple=16,
    )
