"""Mixtral-8x7B [arXiv:2401.04088]: 8 experts top-2, sliding-window attention."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, moe_d_ff=128, vocab_size=512, num_experts=4, num_experts_per_tok=2,
        sliding_window=16, vocab_pad_multiple=16,
    )
