"""Logical-axis sharding: named rule sets + the ``ShardCtx`` carried in ``Ctx``.

Models never name mesh axes directly.  Parameters declare *logical* axes
(``embed``, ``heads_flat``, ``mlp``, ...) in their ``PSpec``; activations are
annotated through :meth:`ShardCtx.constrain` with per-dimension logical
names.  A *rule set* — an ordered tuple of ``(logical_axis, mesh_axes)``
pairs — maps those names onto whatever mesh the job actually has.  The same
model code therefore runs unmodified on one device (every method degrades to
a no-op), the 16-fake-device test mesh, and the 512-chip production mesh.

Resolution semantics (applied per tensor, per dimension):

* rules may name mesh axes the current mesh lacks (e.g. ``pod`` on a
  single-pod mesh) — absent axes are silently dropped;
* a mesh axis is used at most once per tensor (first dimension wins);
* an assignment must divide the dimension evenly, else trailing mesh axes
  are peeled off until it does (falling back to unsharded).

``RULE_SETS`` registers the named sets the launcher selects between:
``default`` (TP over ``model``, batch over ``pod``+``data``, and FSDP-style
parameter sharding of the ``embed`` dimension over ``data``) and
``no_fsdp`` (same minus the parameter sharding — every non-TP parameter
dimension stays replicated).
"""
from __future__ import annotations

import dataclasses
import math
from functools import cached_property
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = tuple[tuple[str, tuple[str, ...]], ...]

DEFAULT_RULES: Rules = (
    ("batch", ("pod", "data")),
    ("embed", ("data",)),  # FSDP: shard the param embed dim over data
    ("heads_flat", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("mlp", ("model",)),
    ("vocab", ("model",)),
    ("qseq", ("model",)),
    ("seq_kv", ("model",)),
    ("experts", ()),
    ("layers", ()),
)

NO_FSDP_RULES: Rules = tuple(
    (name, () if name == "embed" else axes) for name, axes in DEFAULT_RULES
)

RULE_SETS: dict[str, Rules] = {
    "default": DEFAULT_RULES,
    "no_fsdp": NO_FSDP_RULES,
}


def rules_without_axis(rules: Rules, mesh_axis: str) -> Rules:
    """Drop one mesh axis from every rule — e.g. strip ``pod`` before
    entering a shard_map that handles the pod axis manually (inside it,
    ``pod`` is no longer a GSPMD axis and must not appear in constraints).
    """
    return tuple(
        (name, tuple(a for a in axes if a != mesh_axis)) for name, axes in rules
    )


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh + rule set, with every query safe on a mesh-less context."""

    mesh: Optional[Mesh] = None
    rules: Rules = DEFAULT_RULES

    @cached_property
    def _rule_map(self) -> dict[str, tuple[str, ...]]:
        return dict(self.rules)

    def _mesh_axes(self, logical: Any) -> tuple[str, ...]:
        """Mesh axes (present in this mesh) one logical axis maps to."""
        if self.mesh is None or logical is None:
            return ()
        rule = self._rule_map.get(logical, ())
        return tuple(a for a in rule if a in self.mesh.shape)

    # -- size queries ------------------------------------------------------
    def axis_size(self, *names: str) -> int:
        """Product of the named mesh axes' sizes; 0 if none exist."""
        if self.mesh is None:
            return 0
        present = [self.mesh.shape[n] for n in names if n in self.mesh.shape]
        return math.prod(present) if present else 0

    @property
    def data_axes(self) -> tuple[str, ...]:
        return self._mesh_axes("batch")

    @property
    def model_axes(self) -> tuple[str, ...]:
        return self._mesh_axes("mlp")

    def heads_shardable(self, n: int) -> bool:
        tp = self.axis_size(*self.model_axes) if self.model_axes else 0
        return tp > 1 and n % tp == 0

    # -- spec / sharding construction --------------------------------------
    def spec(self, axes, shape) -> P:
        """PartitionSpec for per-dim logical axis names against a shape."""
        assert self.mesh is not None
        used: set[str] = set()
        parts = []
        for dim, logical in zip(shape, axes):
            cand = tuple(a for a in self._mesh_axes(logical) if a not in used)
            while cand and dim % math.prod(self.mesh.shape[a] for a in cand):
                cand = cand[:-1]  # peel until the assignment divides evenly
            used.update(cand)
            parts.append(cand if len(cand) > 1 else (cand[0] if cand else None))
        return P(*parts)

    def sharding(self, axes, shape) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def param_sharding(self, spec) -> Optional[NamedSharding]:
        """Sharding for one parameter declaration (``PSpec``-like: has
        ``.axes`` logical names and ``.shape``)."""
        if self.mesh is None:
            return None
        return self.sharding(spec.axes, spec.shape)

    def constrain(self, x: jax.Array, *axes) -> jax.Array:
        """``with_sharding_constraint`` under the rule set; identity when
        there is no mesh (or a trivial one)."""
        if self.mesh is None or math.prod(self.mesh.shape.values()) == 1:
            return x
        return jax.lax.with_sharding_constraint(
            x,
            NamedSharding(self.mesh, self.spec(axes, x.shape)),
        )
