"""int8 gradient all-reduce with error feedback.

The cross-pod (DCI) hop is the slow link at production scale, so gradients
cross it quantised to int8.  Plain quantisation biases training; the fix
(1-bit SGD / EF-SGD lineage — and the same move LUT-quantisation work makes
when it carries rounding error forward between iterations) is *error
feedback*: whatever the quantiser drops this step is stored per worker and
added back into the gradient before quantising the next step, so the error
is carried, not lost.

Contract of :func:`compressed_psum` (per leaf, per step):

* ``scale`` is shared across the axis (``pmax`` of the compensated
  grad's absmax, / 127) so every worker de-quantises identically;
* the wire payload is the int8 code tensor (summed here as int32 — two
  int8 codes already exceed the int8 range);
* the returned gradient is the across-axis **mean** of the de-quantised
  codes, matching what an uncompressed data-parallel psum-mean computes;
* the returned residual is ``compensated - dequantised`` — bounded by half
  a quantisation step (no clipping can occur: |compensated| <= 127*scale
  by construction of the shared scale).

Designed to run inside a ``shard_map`` that is manual over ``axis_name``
only (the pod axis), with data/model parallelism still handled by GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _compress_one(g: jax.Array, err: jax.Array, axis_name: str):
    c = g.astype(jnp.float32) + err.astype(jnp.float32)  # error compensation
    absmax = jax.lax.pmax(jnp.max(jnp.abs(c)), axis_name)
    scale = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny) / 127.0
    codes = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    dequant = codes.astype(jnp.float32) * scale
    new_err = c - dequant  # carried to the next step
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    total = jax.lax.psum(codes.astype(jnp.int32), axis_name)  # the wire hop
    mean = total.astype(jnp.float32) * scale / n
    return mean.astype(g.dtype), new_err


def compressed_psum(grads_tree, err_tree, axis_name: str):
    """(grads, residuals) -> (mean-reduced grads, new residuals).

    Both trees must share a structure; each leaf is quantised with its own
    per-tensor scale.
    """
    g_leaves, treedef = jax.tree.flatten(grads_tree)
    e_leaves = treedef.flatten_up_to(err_tree)
    outs, errs = [], []
    for g, e in zip(g_leaves, e_leaves):
        o, ne = _compress_one(g, e, axis_name)
        outs.append(o)
        errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(errs)
