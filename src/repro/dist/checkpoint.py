"""Atomic, elastic checkpoints.

Layout: ``<dir>/step_00000123/`` holding ``arrays.npz`` (leaves in tree
order, stored as raw byte buffers so exotic dtypes like bfloat16 survive
numpy serialisation) and ``meta.json`` (per-leaf dtype/shape manifest).

* **atomic** — writes land in a ``.tmp_*`` sibling that is ``os.rename``d
  into place; a crash mid-write can never produce a step directory that
  :func:`latest_step` would pick up (it also requires ``meta.json``).
* **elastic** — checkpoints store full logical arrays (gathered to host),
  so :func:`restore_checkpoint` can place them onto *any* sharding the
  ``like`` tree requests: a different mesh shape, fewer devices, or a
  single host.  Restoring 16-way-sharded training state onto a 4-device
  serving mesh is a plain restore.
* **GC** — ``keep_last=N`` prunes all but the newest N steps after a
  successful commit.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_PREFIX = "step_"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_PREFIX}{step:08d}")


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    keep_last: Optional[int] = None,
    aux: Optional[dict] = None,
) -> str:
    """Commit ``tree`` (any pytree of arrays/scalars) as ``step``.

    ``aux`` is an optional JSON-serializable payload committed atomically
    with the arrays (stored inside ``meta.json``) — e.g. a serialized
    ``core.planner.ModelPlan`` so a converted model restores with the exact
    per-layer LUT plans it was built with.  Read it back with
    :func:`load_aux`.
    """
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    arrays = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    tmp = tempfile.mkdtemp(prefix=".tmp_", dir=directory)
    try:
        np.savez(
            os.path.join(tmp, "arrays.npz"),
            **{
                f"leaf_{i}": np.frombuffer(a.tobytes(), np.uint8)
                for i, a in enumerate(arrays)
            },
        )
        recs = [{"dtype": str(a.dtype), "shape": list(a.shape)} for a in arrays]
        meta = {"step": step, "leaves": recs}
        if aux is not None:
            meta["aux"] = aux
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        final = _step_dir(directory, step)
        aside = None
        if os.path.exists(final):
            # never rmtree a committed step before the replacement lands: a
            # crash in between would lose it; park it aside instead
            aside = tmp + ".old"
            os.rename(final, aside)
        os.rename(tmp, final)  # the commit point
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if aside is not None:
        shutil.rmtree(aside, ignore_errors=True)
    if keep_last is not None:
        assert keep_last >= 1, f"keep_last must be >= 1, got {keep_last}"
        steps = sorted(_list_steps(directory))
        for old in steps[: len(steps) - keep_last]:
            shutil.rmtree(_step_dir(directory, old), ignore_errors=True)
    return _step_dir(directory, step)


def _list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if not name.startswith(_PREFIX):
            continue
        if not os.path.exists(os.path.join(directory, name, "meta.json")):
            continue  # partial/corrupt: never committed
        try:
            steps.append(int(name[len(_PREFIX):]))
        except ValueError:
            continue
    return steps


def latest_step(directory: str) -> Optional[int]:
    """Newest committed step, or None for a missing/empty/partial-only dir."""
    steps = _list_steps(directory)
    return max(steps) if steps else None


def load_aux(directory: str, step: int) -> Optional[dict]:
    """The ``aux`` payload committed with ``step`` (None if absent)."""
    with open(os.path.join(_step_dir(directory, step), "meta.json")) as f:
        return json.load(f).get("aux")


def _place(arr: np.ndarray, like) -> jax.Array:
    """Put one host array onto whatever placement ``like`` requests."""
    sharding = getattr(like, "sharding", None)
    if sharding is not None:
        return jax.device_put(arr, sharding)
    return jnp.asarray(arr)


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore ``step`` shaped/placed like the ``like`` tree.

    ``like`` leaves may be concrete arrays or ``ShapeDtypeStruct``s; a leaf
    carrying a sharding gets the loaded value ``device_put`` onto it —
    including shardings over a different mesh than the checkpoint was saved
    from (elastic restore).
    """
    path = _step_dir(directory, step)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    like_leaves, treedef = jax.tree.flatten(like)
    if len(like_leaves) != len(meta["leaves"]):
        raise ValueError(
            f"checkpoint {path} has {len(meta['leaves'])} leaves, "
            f"restore target has {len(like_leaves)}"
        )
    out = []
    with np.load(os.path.join(path, "arrays.npz")) as z:
        for i, (rec, leaf) in enumerate(zip(meta["leaves"], like_leaves)):
            buf = z[f"leaf_{i}"].tobytes()
            arr = np.frombuffer(buf, np.dtype(rec["dtype"])).reshape(rec["shape"])
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != "
                    f"target shape {np.shape(leaf)}"
                )
            want = getattr(leaf, "dtype", None)
            if want is not None and np.dtype(want) != arr.dtype:
                raise ValueError(
                    f"leaf {i}: checkpoint dtype {arr.dtype} != "
                    f"target dtype {np.dtype(want)}"
                )
            out.append(_place(arr, leaf))
    return treedef.unflatten(out)
