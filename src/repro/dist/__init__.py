"""Distribution layer: logical-axis sharding rules, compressed collectives,
and elastic checkpoints.  See README.md in this package for the contracts.
"""
from repro.dist import checkpoint, compression, sharding  # noqa: F401
from repro.dist.compression import compressed_psum  # noqa: F401
from repro.dist.sharding import RULE_SETS, ShardCtx  # noqa: F401
