"""Hand-rolled AdamW (no optax in this environment) with global-norm clip.

Moments inherit the parameters' shardings through the update computation, so
optimizer state is ZeRO-sharded wherever the params are.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(grads, opt_state, params, lr: jax.Array, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, grad_norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        norm = global_norm(grads)
    step = opt_state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, norm
