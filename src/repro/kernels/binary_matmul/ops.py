"""Jit'd wrapper for the bitplane binary matmul."""
from __future__ import annotations

import functools

import jax

from repro.kernels.binary_matmul.binary_matmul import binary_matmul_pallas
from repro.kernels.common import ceil_to, default_interpret, pad_axis


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_p", "block_q", "interpret")
)
def _bmm(planes, W, scales, block_b, block_p, block_q, interpret):
    return binary_matmul_pallas(
        planes,
        W,
        scales,
        block_b=block_b,
        block_p=block_p,
        block_q=block_q,
        interpret=interpret,
    )


def binary_matmul(
    planes: jax.Array,  # (..., n, q) int8 bitplanes
    W: jax.Array,  # (q, p)
    scales: jax.Array,  # (n,)
    bias: jax.Array | None = None,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    *lead, n, q = planes.shape
    p = W.shape[1]
    B = 1
    for d in lead:
        B *= d
    planes2 = planes.reshape(B, n, q)

    block_b = min(ceil_to(B, 8), 64)
    block_p = min(ceil_to(p, 128), 512)
    block_q = min(ceil_to(q, 128), 512)
    Bp, pp, qp = ceil_to(B, block_b), ceil_to(p, block_p), ceil_to(q, block_q)
    planes2 = pad_axis(pad_axis(planes2, 0, Bp), 2, qp)
    Wp = pad_axis(pad_axis(W, 0, qp), 1, pp)

    out = _bmm(planes2, Wp, scales, block_b, block_p, block_q, interpret)[:B, :p]
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return out.reshape(*lead, p)
