"""Oracle for the bitplane binary matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binary_matmul_ref(planes: jax.Array, W: jax.Array, scales: jax.Array) -> jax.Array:
    """out[b] = sum_j scales[j] * planes[b, j] @ W  (bf16 inputs, f32 accum,
    mirroring the kernel's MXU dtype path)."""
    prod = jnp.einsum(
        "bnq,qp->bnp",
        planes.astype(jnp.bfloat16),
        W.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    return jnp.einsum("bnp,n->bp", prod, scales.astype(jnp.float32))
