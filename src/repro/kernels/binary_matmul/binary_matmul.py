"""Pallas TPU kernel: bitplane binary matmul (beyond-paper MXU path).

Mathematically identical to the paper's bitplane LUT with chunk size 1 — a
2-entry table ``{0, w_i}`` *is* multiplication by a bit — but re-expressed
so the systolic array does the accumulation:

    out[b, :] = sum_j scales[j] * (planes[b, j, :] @ W)

``planes`` is the {0,1} bitplane tensor (int8), ``W`` the full-precision
weights.  The n plane rows fold into the matmul M dimension, so one
``(bb*n, qb) @ (qb, pb)`` MXU contraction per grid step; the shift-and-add
(scale per plane) happens in-register on the (bb, n, pb) product.  Arithmetic
intensity is that of a matmul instead of the O(1) gather path — this is the
mode that moves LUT serving from the memory roofline to the compute
roofline on TPU.

Grid: (batch_tiles, out_tiles, in_tiles); in_tiles accumulate into the
revisited output block.  fp32 accumulation throughout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(planes_ref, w_ref, scales_ref, out_ref):
    """planes_ref: (bb, n, qb) int8; w_ref: (qb, pb); scales_ref: (n, 1) f32;
    out_ref: (bb, pb) f32 (revisited over the q grid axis)."""
    qt = pl.program_id(2)

    @pl.when(qt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bb, n, qb = planes_ref.shape
    lhs = planes_ref[...].astype(jnp.bfloat16).reshape(bb * n, qb)
    prod = jnp.dot(
        lhs, w_ref[...].astype(jnp.bfloat16), preferred_element_type=jnp.float32
    )  # (bb*n, pb) on the MXU
    prod = prod.reshape(bb, n, out_ref.shape[1])
    out_ref[...] += jnp.einsum(
        "bnp,n->bp", prod, scales_ref[:, 0], preferred_element_type=jnp.float32
    )


def binary_matmul_pallas(
    planes: jax.Array,  # (B, n, q) int8 in {0, 1}
    W: jax.Array,  # (q, p)
    scales: jax.Array,  # (n,) f32
    *,
    block_b: int,
    block_p: int,
    block_q: int,
    interpret: bool,
) -> jax.Array:
    B, n, q = planes.shape
    q2, p = W.shape
    assert q == q2
    assert B % block_b == 0 and p % block_p == 0 and q % block_q == 0
    return pl.pallas_call(
        _kernel,
        grid=(B // block_b, p // block_p, q // block_q),
        in_specs=[
            pl.BlockSpec((block_b, n, block_q), lambda b, o, i: (b, 0, i)),
            pl.BlockSpec((block_q, block_p), lambda b, o, i: (i, o)),
            pl.BlockSpec((n, 1), lambda b, o, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_p), lambda b, o, i: (b, o)),
        out_shape=jax.ShapeDtypeStruct((B, p), jnp.float32),
        interpret=interpret,
    )(planes, W, scales.reshape(n, 1).astype(jnp.float32))
