"""Shared helpers for the Pallas TPU kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pad_axis(x: jax.Array, axis: int, to: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to length ``to``."""
    cur = x.shape[axis]
    if cur == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - cur)
    return jnp.pad(x, pads, constant_values=value)


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU hosts we run the kernel body in
    interpret mode (bit-identical semantics, executed by XLA:CPU)."""
    return jax.default_backend() != "tpu"
