"""Shared helpers for the Pallas TPU kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Largest |value| each accumulator dtype can hold exactly enough for the
# contract check: integer dtypes their max code, float32 its max finite.
# Keys are the strings a plan's ``acc_dtype`` field carries.
ACC_CAPACITY: dict[str, float] = {
    "int16": float(2**15 - 1),
    "int32": float(2**31 - 1),
    "int64": float(2**63 - 1),
    "float32": float(np.finfo(np.float32).max),
}


def acc_capacity(acc_dtype: str) -> float:
    """Capacity of an accumulator dtype name (raises on unknown names)."""
    try:
        return ACC_CAPACITY[acc_dtype]
    except KeyError:
        raise ValueError(
            f"unknown accumulator dtype {acc_dtype!r}; "
            f"expected one of {sorted(ACC_CAPACITY)}"
        ) from None


def check_acc_contract(op: str, plan, kernel_acc_dtype: str) -> None:
    """Trace-time accumulator-contract assert.

    ``plan`` is duck-typed (any object with ``acc_dtype`` and a proved
    ``max_abs_acc`` stamped by the planner via ``repro.audit.ranges``).
    No-op when the plan carries no proved bound; otherwise raises if the
    bound exceeds either the plan's *declared* accumulator capacity or the
    capacity of the dtype this kernel actually accumulates in.  Runs at
    trace time — a violating plan can never reach execution.
    """
    bound = getattr(plan, "max_abs_acc", None)
    if bound is None:
        return
    declared = plan.acc_dtype
    if bound > acc_capacity(declared):
        raise ValueError(
            f"{op}: plan declares acc_dtype={declared!r} but its proved "
            f"|acc| bound {bound:.6g} exceeds that dtype's capacity "
            f"{acc_capacity(declared):.6g}"
        )
    if bound > acc_capacity(kernel_acc_dtype):
        raise ValueError(
            f"{op}: kernel accumulates in {kernel_acc_dtype}, too narrow "
            f"for the plan's proved |acc| bound {bound:.6g}"
        )


def ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pad_axis(x: jax.Array, axis: int, to: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to length ``to``."""
    cur = x.shape[axis]
    if cur == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - cur)
    return jnp.pad(x, pads, constant_values=value)


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU hosts we run the kernel body in
    interpret mode (bit-identical semantics, executed by XLA:CPU)."""
    return jax.default_backend() != "tpu"
