"""Jit'd wrapper for the packing kernel: padding + block choice + reshapes."""
from __future__ import annotations

import functools

import jax

from repro.kernels.bitplane_pack.bitplane_pack import bitplane_pack_pallas
from repro.kernels.common import ceil_to, default_interpret, pad_axis


@functools.partial(
    jax.jit,
    static_argnames=(
        "kind",
        "bits",
        "frac",
        "signed",
        "m",
        "block_b",
        "block_k",
        "interpret",
    ),
)
def _packed(x, kind, bits, frac, signed, m, block_b, block_k, interpret):
    return bitplane_pack_pallas(
        x,
        kind=kind,
        bits=bits,
        frac=frac,
        signed=signed,
        m=m,
        block_b=block_b,
        block_k=block_k,
        interpret=interpret,
    )


def bitplane_pack(
    x: jax.Array,  # (..., q)
    *,
    kind: str,
    m: int,
    bits: int = 16,
    frac: int = 0,
    signed: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """(..., q) -> (..., n_planes, k) LUT indices (see kernel docstring)."""
    if interpret is None:
        interpret = default_interpret()
    *lead, q = x.shape
    B = 1
    for d in lead:
        B *= d
    k = -(-q // m)
    x2 = pad_axis(x.reshape(B, q), 1, k * m)

    block_k = min(ceil_to(k, 8), 256)
    block_b = min(ceil_to(B, 8), 128)
    Bp, kp = ceil_to(B, block_b), ceil_to(k, block_k)
    x2 = pad_axis(x2, 0, Bp)
    x2 = pad_axis(x2, 1, kp * m)

    out = _packed(x2, kind, bits, frac, signed, m, block_b, block_k, interpret)
    n = out.shape[1]
    return out[:B, :, :k].reshape(*lead, n, k)
