"""Pallas TPU kernel for TableNet input packing.

Fuses quantisation + bitplane extraction + chunk-index packing — the step
the paper assumes dedicated bit-routing hardware for.  On TPU this is pure
VPU work (shifts, masks, small reductions) and would otherwise cost several
HBM round-trips as separate XLA ops.

  fixed : x -> code = clip(round(x / 2^-f))        (two's complement bits)
          out[b, j, c] = sum_i bit_j(code[b, c*m+i]) << i
  fp16  : x -> h = fp16(max(x, 0)); fields = (mantissa_bit_j << 5) | exponent
          out[b, j, c] = sum_i field_j(h[b, c*m+i]) << (6*i)

Plane 10 of fp16 is the implicit leading bit (exp > 0), per the paper's
Fig. 1 treatment of normals/subnormals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack(fields: jax.Array, m: int, stride: int) -> jax.Array:
    """(bb, kb*m) int32 fields -> (bb, kb) packed indices."""
    bb, qb = fields.shape
    chunked = fields.reshape(bb, qb // m, m)
    shifts = (jnp.arange(m, dtype=jnp.int32) * stride)[None, None, :]
    return jnp.sum(chunked << shifts, axis=-1, dtype=jnp.int32)


def _fixed_kernel(x_ref, out_ref, *, bits, frac, signed, m):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.float32(2.0**-frac)
    lo = -(2 ** (bits - 1)) if signed else 0
    hi = 2 ** (bits - 1) - 1 if signed else 2**bits - 1
    code = jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int32)
    u = jnp.where(code < 0, code + 2**bits, code) if signed else code
    for j in range(bits):
        out_ref[:, j, :] = _pack((u >> j) & 1, m, 1)


def _float16_kernel(x_ref, out_ref, *, m):
    h = jnp.maximum(x_ref[...], 0.0).astype(jnp.float16)
    u = jax.lax.bitcast_convert_type(h, jnp.uint16).astype(jnp.int32)
    exp = (u >> 10) & 0x1F
    man = u & 0x3FF
    for j in range(10):
        field = (((man >> j) & 1) << 5) | exp
        out_ref[:, j, :] = _pack(field, m, 6)
    implicit = ((exp > 0).astype(jnp.int32) << 5) | exp
    out_ref[:, 10, :] = _pack(implicit, m, 6)


def bitplane_pack_pallas(
    x: jax.Array,  # (B, k*m) already padded
    *,
    kind: str,  # "fixed" | "float16"
    bits: int,
    frac: int,
    signed: bool,
    m: int,
    block_b: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    B, q = x.shape
    k = q // m
    n = 11 if kind == "float16" else bits
    assert B % block_b == 0 and k % block_k == 0
    if kind == "float16":
        kernel = functools.partial(_float16_kernel, m=m)
    else:
        kernel = functools.partial(
            _fixed_kernel, bits=bits, frac=frac, signed=signed, m=m
        )
    return pl.pallas_call(
        kernel,
        grid=(B // block_b, k // block_k),
        in_specs=[pl.BlockSpec((block_b, block_k * m), lambda b, c: (b, c))],
        out_specs=pl.BlockSpec((block_b, n, block_k), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, n, k), jnp.int32),
        interpret=interpret,
    )(x)
