"""Oracle for the packing kernel: the core-library pack_codes path."""
from __future__ import annotations

import jax

from repro.core.lut import LUTPlan, pack_codes
from repro.core.quantize import FixedPointFormat, Float16Format


def bitplane_pack_ref(x: jax.Array, *, kind, bits, frac, signed, m) -> jax.Array:
    q = x.shape[-1]
    fmt = Float16Format() if kind == "float16" else FixedPointFormat(bits, frac, signed)
    plan = LUTPlan(q, 1, m, fmt, mode="bitplane")
    return pack_codes(x, plan)
