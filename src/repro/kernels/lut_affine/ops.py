"""Jit'd public wrapper around the LUT affine Pallas kernel.

Handles padding to block multiples, block-size selection under a VMEM
budget, bias, and arbitrary leading batch dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    ceil_to,
    check_acc_contract,
    default_interpret,
    pad_axis,
)
from repro.kernels.lut_affine.lut_affine import (
    lut_affine_experts_pallas,
    lut_affine_grouped_pallas,
    lut_affine_pallas,
)

_VMEM_BUDGET = 4 * 2**20  # bytes of live blocks per grid step


def _pick_blocks(B: int, k: int, E: int, p: int, n: int, G: int = 1):
    """Block sizes keeping live table tiles under ``_VMEM_BUDGET``.

    ``G`` is the group dimension of :func:`lut_affine_grouped`: grouped
    dispatches keep ``G`` projections' table tiles in flight across the
    group-major grid, so the budget accounting scales by ``G`` (omitting it
    let grouped blocks exceed the budget by up to ``G``x).
    """
    block_p = min(ceil_to(p, 128), 512)
    # tables dominate VMEM: G * kb * E * pb * 4 <= budget.  Shrink in
    # 128-multiples only — Mosaic needs lane-dim blocks of 128.
    while block_p > 128 and G * E * block_p * 4 > _VMEM_BUDGET:
        block_p = max(128, (block_p // 2 + 127) // 128 * 128)
    block_b = min(ceil_to(B, 8), 128)
    max_kb = max(1, _VMEM_BUDGET // (G * E * block_p * 4))
    block_k = 1
    while block_k * 2 <= min(max_kb, k):
        block_k *= 2
    return block_b, block_p, block_k


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_p", "block_k", "interpret", "shift_bits"),
)
def _lut_affine_padded(
    codes, tables, scales, block_b, block_p, block_k, interpret, shift_bits
):
    return lut_affine_pallas(
        codes,
        tables,
        scales,
        block_b=block_b,
        block_p=block_p,
        block_k=block_k,
        interpret=interpret,
        shift_bits=shift_bits,
    )


def lut_affine(
    codes: jax.Array,  # (..., n, k) int32
    tables: jax.Array,  # (k, E, p)
    scales: jax.Array,  # (n,)
    bias: jax.Array | None = None,
    *,
    interpret: bool | None = None,
    blocks: tuple[int, int, int] | None = None,
    shift_bits: int = 0,
    plan=None,
) -> jax.Array:
    """out[..., :] = sum_j scales[j] * sum_c tables[c, codes[..., j, c], :] + bias

    ``blocks`` overrides the static ``_pick_blocks`` heuristic with autotuned
    ``(block_b, block_p, block_k)`` tile sizes (see ``autotune.py``);
    ``shift_bits`` selects the ``bitplane_shift`` code contract; ``plan``
    (a ``LUTPlan``) asserts the accumulator contract at trace time when it
    carries a proved ``max_abs_acc`` (this kernel accumulates fp32)."""
    if plan is not None:
        check_acc_contract("lut_affine", plan, "float32")
    if interpret is None:
        interpret = default_interpret()
    *lead, n, k = codes.shape
    k2, E, p = tables.shape
    assert k == k2, f"codes have {k} chunks, tables {k2}"  # before padding
    B = 1
    for d in lead:
        B *= d
    codes2 = codes.reshape(B, n, k)

    block_b, block_p, block_k = blocks or _pick_blocks(B, k, E, p, n)
    Bp, pp, kp = ceil_to(B, block_b), ceil_to(p, block_p), ceil_to(k, block_k)
    codes2 = pad_axis(pad_axis(codes2, 0, Bp), 2, kp)
    # padded chunks index entry 0 of a zero table -> contribute nothing
    tables_p = pad_axis(pad_axis(tables, 0, kp), 2, pp)

    out = _lut_affine_padded(
        codes2, tables_p, scales, block_b, block_p, block_k, interpret, shift_bits
    )[:B, :p]
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return out.reshape(*lead, p)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_p", "block_k", "interpret", "shift_bits"),
)
def _lut_affine_grouped_padded(
    codes, tables, scales, block_b, block_p, block_k, interpret, shift_bits
):
    return lut_affine_grouped_pallas(
        codes,
        tables,
        scales,
        block_b=block_b,
        block_p=block_p,
        block_k=block_k,
        interpret=interpret,
        shift_bits=shift_bits,
    )


def lut_affine_grouped(
    codes: jax.Array,  # (..., n, k) int32 — one packed input for the group
    tables: jax.Array,  # (G, k, E, p) — same-shape projections, pre-stacked
    scales: jax.Array,  # (n,)
    biases: jax.Array | None = None,  # (G, p)
    *,
    interpret: bool | None = None,
    blocks: tuple[int, int, int] | None = None,
    shift_bits: int = 0,
    plan=None,
) -> jax.Array:
    """Fused batched decode path: ``out[g, ..., :] = lut_affine(codes,
    tables[g], scales) (+ biases[g])`` for all ``G`` projections in ONE
    Pallas grid — one dispatch per decode step for a whole QKV or gate/up
    group instead of one per projection.  ``tables`` is exactly the leaf a
    converted ``core.convert.LUTGroup`` stores (stacked once at conversion
    time), so serving never re-stacks per step."""
    if plan is not None:
        check_acc_contract("lut_affine_grouped", plan, "float32")
    if interpret is None:
        interpret = default_interpret()
    *lead, n, k = codes.shape
    G, k2, E, p = tables.shape
    assert k == k2, f"codes have {k} chunks, tables {k2}"  # before padding
    B = 1
    for d in lead:
        B *= d
    codes2 = codes.reshape(B, n, k)

    block_b, block_p, block_k = blocks or _pick_blocks(B, k, E, p, n, G=G)
    Bp, pp, kp = ceil_to(B, block_b), ceil_to(p, block_p), ceil_to(k, block_k)
    codes2 = pad_axis(pad_axis(codes2, 0, Bp), 2, kp)
    # padded chunks index entry 0 of a zero table -> contribute nothing
    tables_p = pad_axis(pad_axis(tables, 1, kp), 3, pp)

    out = _lut_affine_grouped_padded(
        codes2, tables_p, scales, block_b, block_p, block_k, interpret, shift_bits
    )[:, :B, :p]
    if biases is not None:
        out = out + biases[:, None, :].astype(out.dtype)
    return out.reshape(G, *lead, p)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_p", "block_k", "interpret", "shift_bits"),
)
def _lut_affine_experts_padded(
    offsets, codes, tables, scales, block_b, block_p, block_k, interpret, shift_bits
):
    return lut_affine_experts_pallas(
        offsets,
        codes,
        tables,
        scales,
        block_b=block_b,
        block_p=block_p,
        block_k=block_k,
        interpret=interpret,
        shift_bits=shift_bits,
    )


def lut_affine_experts(
    codes: jax.Array,  # (T, n, k) int32 — tokens sorted by expert
    tables: jax.Array,  # (E, G, k, En, p) — pre-stacked expert tables
    scales: jax.Array,  # (n,)
    group_sizes: jax.Array,  # (E,) int32 tokens per expert, sum == T
    *,
    interpret: bool | None = None,
    blocks: tuple[int, int, int] | None = None,
    shift_bits: int = 0,
    plan=None,
) -> jax.Array:
    """Ragged MoE dispatch over pre-stacked expert tables: token row ``t``
    (sorted by expert, the ``lax.ragged_dot`` layout) is evaluated against
    its expert's ``tables[e]`` for all ``G`` fused projections in ONE grid —
    the LUT-affine replacement for a grouped GEMM.  ``tables`` is exactly
    the scan-sliced leaf a converted expert ``LUTGroup`` stores (a lone
    ``LUTLinear`` stack passes ``tables[:, None]``)."""
    if plan is not None:
        check_acc_contract("lut_affine_experts", plan, "float32")
    if interpret is None:
        interpret = default_interpret()
    T, n, k = codes.shape
    E, G, k2, En, p = tables.shape
    assert k == k2, f"codes have {k} chunks, tables {k2}"  # before padding
    assert group_sizes.shape == (E,), (group_sizes.shape, E)

    block_b, block_p, block_k = blocks or _pick_blocks(T, k, En, p, n)
    Tp, pp, kp = ceil_to(T, block_b), ceil_to(p, block_p), ceil_to(k, block_k)
    codes2 = pad_axis(pad_axis(codes, 0, Tp), 2, kp)
    # padded chunks index entry 0 of a zero table -> contribute nothing;
    # padded token rows sit past offsets[-1] -> outside every expert's row
    # range -> left at the kernel's zero init and sliced off below
    tables_p = pad_axis(pad_axis(tables, 2, kp), 4, pp)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes.astype(jnp.int32))]
    )

    out = _lut_affine_experts_padded(
        offsets,
        codes2,
        tables_p,
        scales,
        block_b,
        block_p,
        block_k,
        interpret,
        shift_bits,
    )[:, :T, :p]
    return out
