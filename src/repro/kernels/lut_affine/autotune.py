"""Autotuner for LUT-affine Pallas block shapes.

``ops._pick_blocks`` is a one-shot heuristic: it maximises the chunk tile
under a VMEM budget and fixes the batch tile at ``min(B, 128)``.  That is a
fine default, but the best ``(block_b, block_p, block_k)`` tiling depends on
the *shape point* a dispatch actually presents — decode batch, chunk count,
entry count, output width, plane count, group fan-out — and the trade-offs
(grid-step overhead vs table-tile DMA vs padding waste) move against each
other as those vary.

This module searches the candidate tilings for a shape point and returns a
winner that callers persist on the layer's :class:`~repro.core.lut.LUTPlan`
(``plan.blocks``).  Plans JSON-round-trip through ``ModelPlan`` and ride
checkpoints, so a tuned serving process restores with its tilings intact and
``models.layers`` / ``models.moe`` dispatch the kernels with them directly.

Two search modes:

* ``analytic`` (default) — a deterministic cost model: grid steps times a
  per-step cost of fixed overhead + table/code tile DMA + gather-accumulate
  work.  Padding waste is captured because step counts use padded sizes.
  Fully reproducible across hosts, so CI can re-search the committed
  baseline points and fail on drift (``python -m
  repro.kernels.lut_affine.autotune check``).
* ``measured`` — wall-clock the real kernel (interpret mode off-TPU) over
  the candidate set.  Slower and machine-dependent; for hand tuning, not CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Iterable, Mapping, Sequence

from repro.kernels.common import ceil_to

# Cost-model constants (arbitrary units; only ratios matter).  A grid step
# pays a fixed dispatch/pipeline overhead, one byte of tile DMA costs DMA,
# and one gathered-and-accumulated output element costs FMA.
_STEP_OVERHEAD = 4096.0
_DMA = 1.0
_FMA = 0.25

_VMEM_BUDGET = 4 * 2**20  # keep in lock-step with ops._VMEM_BUDGET


@dataclasses.dataclass(frozen=True)
class TunePoint:
    """The shape a LUT dispatch presents to the kernel (either family).

    For ``family="tl1"`` the axes reinterpret: ``k`` counts *packed bytes*
    along the input (the ``lut_tl1`` chunk axis), ``entries`` is the 9-entry
    per-pair activation LUT, ``n`` is 1 and ``table_bytes`` 1 (uint8 packed
    indices).
    """

    B: int  # batch rows per dispatch (decode: batch size)
    k: int  # chunks (tl1: packed bytes)
    entries: int  # table entries per chunk (tl1: 9)
    p: int  # output features
    n: int  # planes (tl1: 1)
    G: int = 1  # grouped fan-out (1 = ungrouped)
    table_bytes: int = 4  # bytes per stored table element (4/2/1)
    family: str = "weight"  # table family: "weight" | "tl1"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Mapping) -> "TunePoint":
        # "family" is a string and absent from pre-TL1 baseline rows
        vals = {
            f.name: int(d[f.name])
            for f in dataclasses.fields(cls)
            if f.name != "family"
        }
        return cls(**vals, family=str(d.get("family", "weight")))

    @classmethod
    def from_plan(cls, plan, batch: int, G: int = 1) -> "TunePoint":
        if plan.table_family == "tl1":
            return cls(
                B=int(batch),
                k=plan.packed_chunks,
                entries=plan.num_entries,
                p=plan.out_features,
                n=1,
                G=int(G),
                table_bytes=1,
                family="tl1",
            )
        from repro.core.lut import plane_scales

        return cls(
            B=int(batch),
            k=plan.num_chunks,
            entries=plan.num_entries,
            p=plan.out_features,
            n=len(plane_scales(plan)),
            G=int(G),
            table_bytes=max(1, plan.storage_bits // 8),
        )


def table_tile_bytes(pt: TunePoint, blocks: tuple[int, int, int]) -> int:
    """Live table-tile bytes a ``blocks`` tiling keeps resident for ``pt``,
    with the same ``G``-aware accounting as ``ops._pick_blocks``."""
    _, bp, bk = blocks
    if pt.family == "tl1":
        # the packed-index tile is plain bytes — no entries axis
        return pt.G * bk * bp * pt.table_bytes
    return pt.G * bk * pt.entries * bp * pt.table_bytes


def blocks_fit_vmem(pt: TunePoint, blocks: tuple[int, int, int]) -> bool:
    """Whether a tiling's live table tile fits the kernels' VMEM budget.

    The reusable legality predicate: ``candidate_blocks`` enumerates with
    it, and ``repro.audit``'s plan-consistency rule re-checks any ``blocks``
    riding a ``ModelPlan`` against the same budget.
    """
    return table_tile_bytes(pt, tuple(blocks)) <= _VMEM_BUDGET


def candidate_blocks(pt: TunePoint) -> list[tuple[int, int, int]]:
    """All legal ``(block_b, block_p, block_k)`` tilings for ``pt``.

    Legality mirrors the kernel's constraints: the batch tile is a multiple
    of 8 (sublane), the output tile a multiple of 128 (lane), the chunk tile
    a power of two, and the live table tiles fit the VMEM budget
    (:func:`blocks_fit_vmem`).
    """
    bbs = [bb for bb in (8, 16, 32, 64, 128) if bb <= ceil_to(pt.B, 8) * 2]
    bps = [bp for bp in (128, 256, 512) if bp <= ceil_to(pt.p, 128)]
    bks, bk = [], 1
    while bk <= pt.k:
        bks.append(bk)
        bk *= 2
    return [
        (bb, bp, bk)
        for bb in bbs
        for bp in bps
        for bk in bks
        if blocks_fit_vmem(pt, (bb, bp, bk))
    ]


def analytic_cost(pt: TunePoint, blocks: tuple[int, int, int]) -> float:
    """Deterministic cost of running ``pt`` with ``blocks`` (lower = better)."""
    bb, bp, bk = blocks
    steps = (
        (ceil_to(pt.B, bb) // bb)
        * (ceil_to(pt.p, bp) // bp)
        * (ceil_to(pt.k, bk) // bk)
        * pt.G
    )
    if pt.family == "tl1":
        # per step: packed-byte tile + activation-code tile DMA; work is the
        # in-kernel 9-entry LUT build (2 per byte) plus two p-wide gathers
        # per packed byte
        table_tile = bk * bp * pt.table_bytes
        codes_tile = bb * 4 * bk * 4
        work = bb * bk * (2 * pt.entries + 2 * bp)
        return steps * (
            _STEP_OVERHEAD + _DMA * (table_tile + codes_tile) + _FMA * work
        )
    table_tile = bk * pt.entries * bp * pt.table_bytes
    codes_tile = bb * pt.n * bk * 4
    gather = bb * pt.n * bk * bp  # rows gathered x width, accumulated
    return steps * (_STEP_OVERHEAD + _DMA * (table_tile + codes_tile) + _FMA * gather)


def _measure(pt: TunePoint, blocks: tuple[int, int, int], reps: int = 5) -> float:
    """Median wall-clock seconds of the real (or interpreted) kernel."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.lut_affine.ops import lut_affine, lut_affine_grouped

    key = jax.random.PRNGKey(0)
    if pt.family == "tl1":
        from repro.kernels.lut_tl1.ops import lut_tl1, lut_tl1_grouped

        acts = jax.random.randint(key, (pt.B, 4 * pt.k), -127, 128, jnp.int32)
        tshape = (pt.k, pt.p) if pt.G == 1 else (pt.G, pt.k, pt.p)
        packed = jnp.zeros(tshape, jnp.uint8)

        def run_tl1():
            if pt.G > 1:
                return lut_tl1_grouped(acts, packed, blocks=blocks)
            return lut_tl1(acts, packed, blocks=blocks)

        run_tl1().block_until_ready()
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            run_tl1().block_until_ready()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    codes = jax.random.randint(key, (pt.B, pt.n, pt.k), 0, pt.entries, jnp.int32)
    dt = {1: jnp.int8, 2: jnp.int16, 4: jnp.float32}[pt.table_bytes]
    tshape = (pt.k, pt.entries, pt.p)
    if pt.G > 1:
        tshape = (pt.G,) + tshape
    tables = jnp.zeros(tshape, dt)
    scales = jnp.ones((pt.n,), jnp.float32)

    def run():
        if pt.G > 1:
            return lut_affine_grouped(codes, tables, scales, blocks=blocks)
        return lut_affine(codes, tables, scales, blocks=blocks)

    run().block_until_ready()  # compile outside the timed region
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run().block_until_ready()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def search_blocks(
    pt: TunePoint, mode: str = "analytic", reps: int = 5
) -> tuple[int, int, int]:
    """Best ``(block_b, block_p, block_k)`` for ``pt`` under ``mode``.

    Ties break lexicographically on the tiling itself, so the analytic
    winner is a pure function of the point — the property the CI drift
    check relies on.
    """
    cands = candidate_blocks(pt)
    if not cands:  # entries * 128 alone busts the budget: defer to heuristic
        return None
    if mode == "analytic":
        return min(cands, key=lambda blk: (analytic_cost(pt, blk), blk))
    if mode == "measured":
        return min(cands, key=lambda blk: (_measure(pt, blk, reps), blk))
    raise ValueError(f"unknown autotune mode {mode!r}")


# ---------------------------------------------------------------------------
# ModelPlan integration
# ---------------------------------------------------------------------------


def _group_sizes(mplan) -> dict[str, int]:
    """Layer key -> fused fan-out G (members of the same pre-stacked group)."""
    sizes: dict[str, int] = {}
    for group in mplan.groups:
        for key in group:
            sizes[key] = len(group)
    return sizes


def attach_tuned_blocks(mplan, batch: int, mode: str = "analytic"):
    """Return ``mplan`` with every layer plan's ``blocks`` set to the tuned
    tiling for a ``batch``-row dispatch (decode: the serving batch size).

    Group members share one plan object in spirit; the knapsack already
    assigns them identical plans, and the same ``(point -> blocks)`` search
    keeps them identical after tuning, so pre-stacked groups still fuse.
    """
    sizes = _group_sizes(mplan)
    layers = {}
    for key, plan in mplan.layers.items():
        pt = TunePoint.from_plan(plan, batch, G=sizes.get(key, 1))
        layers[key] = dataclasses.replace(plan, blocks=search_blocks(pt, mode))
    return dataclasses.replace(mplan, layers=layers)


# ---------------------------------------------------------------------------
# Baseline file + drift check (CI)
# ---------------------------------------------------------------------------


def points_from_model_plan(mplan, batch: int) -> list[TunePoint]:
    """Deduplicated shape points a ModelPlan dispatches at ``batch`` rows."""
    sizes = _group_sizes(mplan)
    seen: dict[TunePoint, None] = {}
    for key, plan in sorted(mplan.layers.items()):
        seen.setdefault(TunePoint.from_plan(plan, batch, G=sizes.get(key, 1)))
    return list(seen)


def write_baseline(path: str, points: Iterable[TunePoint], mode: str = "analytic"):
    rows = []
    for pt in points:
        blocks = search_blocks(pt, mode)
        rows.append({**pt.to_json(), "blocks": list(blocks) if blocks else None})
    payload = {"mode": mode, "points": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload


def check_baseline(path: str) -> list[str]:
    """Re-search every recorded point; return human-readable mismatches."""
    with open(path) as f:
        payload = json.load(f)
    errs = []
    for row in payload["points"]:
        pt = TunePoint.from_json(row)
        got = search_blocks(pt, payload.get("mode", "analytic"))
        want = tuple(row["blocks"]) if row["blocks"] is not None else None
        if (tuple(got) if got else None) != want:
            errs.append(f"{pt}: committed {want}, re-search found {got}")
    return errs


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("write", "check"):
        sp = sub.add_parser(name)
        sp.add_argument("--baseline", required=True)
        if name == "write":
            sp.add_argument("--mode", default="analytic")
            sp.add_argument(
                "--plan", help="ModelPlan JSON to derive shape points from"
            )
            sp.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)
    if args.cmd == "check":
        errs = check_baseline(args.baseline)
        for e in errs:
            print(f"autotune drift: {e}", file=sys.stderr)
        if errs:
            return 1
        with open(args.baseline) as f:
            n = len(json.load(f)["points"])
        print(f"autotune baseline OK: {n} points re-searched, no drift")
        return 0
    if args.plan:
        from repro.core.planner import ModelPlan

        with open(args.plan) as f:
            mplan = ModelPlan.from_json(json.load(f))
        points = points_from_model_plan(mplan, args.batch)
    else:  # refresh winners for the points already recorded
        with open(args.baseline) as f:
            points = [TunePoint.from_json(r) for r in json.load(f)["points"]]
    write_baseline(args.baseline, points, args.mode)
    print(f"wrote {args.baseline}: {len(points)} points ({args.mode})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
