"""Pure-jnp oracle for the LUT affine kernel (identical contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _sigma_rows(gathered: jax.Array, codes: jax.Array, shift_bits: int):
    """bitplane_shift: codes carry the element exponent above the index
    bits; scale each gathered row by ``2**(max(e,1)-25)``."""
    sig = jnp.exp2(
        jnp.maximum(codes >> shift_bits, 1).astype(jnp.float32) - 25.0
    )
    return gathered * sig[..., None]


def lut_affine_ref(
    codes: jax.Array,  # (B, n, k) int32
    tables: jax.Array,  # (k, E, p)
    scales: jax.Array,  # (n,)
    shift_bits: int = 0,
) -> jax.Array:
    k, E, _ = tables.shape
    idx = codes & (E - 1) if shift_bits else codes
    gathered = tables[jnp.arange(k), idx].astype(jnp.float32)  # (B, n, k, p)
    if shift_bits:
        gathered = _sigma_rows(gathered, codes, shift_bits)
    per_plane = jnp.sum(gathered, axis=-2)  # (B, n, p)
    return jnp.einsum("bnp,n->bp", per_plane, scales.astype(jnp.float32))


def lut_affine_grouped_ref(
    codes: jax.Array,  # (B, n, k) int32 — shared across the group
    tables: jax.Array,  # (G, k, E, p)
    scales: jax.Array,  # (n,)
    shift_bits: int = 0,
) -> jax.Array:
    """(G, B, p): every group member applied to the same packed input."""
    return jax.vmap(lambda t: lut_affine_ref(codes, t, scales, shift_bits))(tables)


def expert_of_token(group_sizes: jax.Array, num_tokens: int) -> jax.Array:
    """(T,) expert id per token for expert-sorted tokens.

    Tokens past ``sum(group_sizes)`` (ragged/padding tail) get id ``E`` —
    one past the last expert — so gathers against ``tables`` must not see
    them; callers slice or mask the tail first.
    """
    ends = jnp.cumsum(group_sizes.astype(jnp.int32))
    rows = jnp.arange(num_tokens, dtype=jnp.int32)
    return jnp.sum(rows[:, None] >= ends[None, :], axis=-1).astype(jnp.int32)


def lut_affine_experts_ref(
    codes: jax.Array,  # (T, n, k) int32 — tokens SORTED by expert
    tables: jax.Array,  # (E, G, k, En, p) pre-stacked per-expert tables
    scales: jax.Array,  # (n,)
    group_sizes: jax.Array,  # (E,) int32, sum == T
    shift_bits: int = 0,
) -> jax.Array:
    """(G, T, p): row ``t`` evaluated against ITS expert's tables.

    The expert-sorted layout is the one ``lax.ragged_dot`` consumes; this is
    its LUT-affine equivalent.  One fused gather per (group member, plane,
    chunk): ``tables[e(t), g, c, codes[t, j, c], :]`` — no per-expert loop
    and no ``(T, ..., entries, p)`` materialisation.
    """
    T = codes.shape[0]
    E, G, k, En, _ = tables.shape
    idx = codes & (En - 1) if shift_bits else codes
    eot = jnp.minimum(expert_of_token(group_sizes, T), E - 1)
    gathered = tables[
        eot[:, None, None, None],  # (T, 1, 1, 1)
        jnp.arange(G, dtype=jnp.int32)[None, :, None, None],
        jnp.arange(k, dtype=jnp.int32)[None, None, None, :],
        idx[:, None, :, :],  # (T, 1, n, k)
    ].astype(jnp.float32)  # (T, G, n, k, p)
    if shift_bits:
        gathered = _sigma_rows(gathered, codes[:, None, :, :], shift_bits)
    per_plane = jnp.sum(gathered, axis=-2)  # (T, G, n, p)
    out = jnp.einsum("tgnp,n->tgp", per_plane, scales.astype(jnp.float32))
    return jnp.moveaxis(out, 0, 1)  # (G, T, p)
