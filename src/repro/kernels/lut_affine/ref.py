"""Pure-jnp oracle for the LUT affine kernel (identical contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_affine_ref(
    codes: jax.Array,  # (B, n, k) int32
    tables: jax.Array,  # (k, E, p)
    scales: jax.Array,  # (n,)
) -> jax.Array:
    k = tables.shape[0]
    gathered = tables[jnp.arange(k), codes]  # (B, n, k, p)
    per_plane = jnp.sum(gathered.astype(jnp.float32), axis=-2)  # (B, n, p)
    return jnp.einsum("bnp,n->bp", per_plane, scales.astype(jnp.float32))


def lut_affine_grouped_ref(
    codes: jax.Array,  # (B, n, k) int32 — shared across the group
    tables: jax.Array,  # (G, k, E, p)
    scales: jax.Array,  # (n,)
) -> jax.Array:
    """(G, B, p): every group member applied to the same packed input."""
    return jax.vmap(lambda t: lut_affine_ref(codes, t, scales))(tables)
