"""Pallas TPU kernel for the paper-faithful LUT affine map.

Computes ``out[b, :] = sum_j scales[j] * sum_c tables[c, codes[b, j, c], :]``
— the TableNet bitplane shift-and-add — with the tables resident in VMEM.

TPU mapping
-----------
The FPGA "RAM read per chunk" becomes a *row gather* from a VMEM-resident
``(entries, p_block)`` tile: the one random-access pattern the TPU memory
system supports at full width (it is the embedding-lookup pattern).  The
grid is ``(batch_tiles, out_tiles, chunk_tiles)``; chunk tiles revisit the
output block and accumulate, so arbitrarily large layers stream through a
fixed VMEM budget:

  VMEM per step = kb * E * pb * 4   (tables)
                + bb * n * kb * 4   (codes)
                + bb * pb * 4       (accumulator)

Block sizes are chosen so this stays under ~4 MiB (cf. ``ops.py``).  The
plane loop is a ``fori_loop`` (n <= 16); the chunk loop is unrolled over the
chunk tile.  All accumulation is fp32 regardless of the table dtype,
matching the paper's full-precision-output claim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, tables_ref, scales_ref, out_ref, *, block_k: int, planes: int):
    """One (batch, out, chunk) grid step.

    codes_ref : (bb, n, kb) int32     VMEM
    tables_ref: (kb, E, pb) f32/bf16  VMEM
    scales_ref: (n, 1) f32            VMEM (2-D for TPU layout friendliness)
    out_ref   : (bb, pb) f32          VMEM (revisited across chunk tiles)
    """
    kt = pl.program_id(2)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def plane_body(j, acc):
        plane = jnp.zeros(out_ref.shape, jnp.float32)
        for c in range(block_k):  # static unroll over the chunk tile
            idx = codes_ref[:, j, c]  # (bb,) int32
            rows = jnp.take(tables_ref[c], idx, axis=0)  # (bb, pb) row gather
            plane = plane + rows.astype(jnp.float32)
        return acc + scales_ref[j, 0] * plane

    acc = jax.lax.fori_loop(
        0, planes, plane_body, jnp.zeros(out_ref.shape, jnp.float32)
    )
    out_ref[...] += acc


def _grouped_kernel(
    codes_ref, tables_ref, scales_ref, out_ref, *, block_k: int, planes: int
):
    """One (group, batch, out, chunk) grid step.

    The codes block is *shared* across the group dimension — the fused
    projections all read the same packed input — so revisiting it per group
    costs no extra packing, only the per-group table tile changes.

    codes_ref : (bb, n, kb) int32       VMEM
    tables_ref: (1, kb, E, pb) f32/bf16 VMEM (leading 1 = this group)
    scales_ref: (n, 1) f32              VMEM
    out_ref   : (1, bb, pb) f32         VMEM (revisited across chunk tiles)
    """
    kt = pl.program_id(3)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def plane_body(j, acc):
        plane = jnp.zeros(out_ref.shape[1:], jnp.float32)
        for c in range(block_k):  # static unroll over the chunk tile
            idx = codes_ref[:, j, c]  # (bb,) int32
            rows = jnp.take(tables_ref[0, c], idx, axis=0)  # (bb, pb)
            plane = plane + rows.astype(jnp.float32)
        return acc + scales_ref[j, 0] * plane

    acc = jax.lax.fori_loop(
        0, planes, plane_body, jnp.zeros(out_ref.shape[1:], jnp.float32)
    )
    out_ref[0] += acc


def lut_affine_grouped_pallas(
    codes: jax.Array,  # (B, n, k) int32, shared by the whole group
    tables: jax.Array,  # (G, k, E, p)
    scales: jax.Array,  # (n,) f32
    *,
    block_b: int,
    block_p: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    """All ``G`` same-shape projections of one decode step in a single grid:
    one Pallas dispatch instead of ``G`` (QKV / gate-up fusion)."""
    B, n, k = codes.shape
    G, k2, E, p = tables.shape
    assert k == k2, (k, k2)
    assert B % block_b == 0 and p % block_p == 0 and k % block_k == 0
    grid = (G, B // block_b, p // block_p, k // block_k)

    kernel = functools.partial(_grouped_kernel, block_k=block_k, planes=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, block_k), lambda g, b, q, c: (b, 0, c)),
            pl.BlockSpec((1, block_k, E, block_p), lambda g, b, q, c: (g, c, 0, q)),
            pl.BlockSpec((n, 1), lambda g, b, q, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_p), lambda g, b, q, c: (g, b, q)),
        out_shape=jax.ShapeDtypeStruct((G, B, p), jnp.float32),
        interpret=interpret,
    )(codes, tables, scales.reshape(n, 1).astype(jnp.float32))


def lut_affine_pallas(
    codes: jax.Array,  # (B, n, k) int32
    tables: jax.Array,  # (k, E, p)
    scales: jax.Array,  # (n,) f32
    *,
    block_b: int,
    block_p: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    B, n, k = codes.shape
    k2, E, p = tables.shape
    assert k == k2, (k, k2)
    assert B % block_b == 0 and p % block_p == 0 and k % block_k == 0
    grid = (B // block_b, p // block_p, k // block_k)

    kernel = functools.partial(_kernel, block_k=block_k, planes=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, block_k), lambda b, q, c: (b, 0, c)),
            pl.BlockSpec((block_k, E, block_p), lambda b, q, c: (c, 0, q)),
            pl.BlockSpec((n, 1), lambda b, q, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_p), lambda b, q, c: (b, q)),
        out_shape=jax.ShapeDtypeStruct((B, p), jnp.float32),
        interpret=interpret,
    )(codes, tables, scales.reshape(n, 1).astype(jnp.float32))
