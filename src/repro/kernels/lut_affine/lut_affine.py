"""Pallas TPU kernel for the paper-faithful LUT affine map.

Computes ``out[b, :] = sum_j scales[j] * sum_c tables[c, codes[b, j, c], :]``
— the TableNet bitplane shift-and-add — with the tables resident in VMEM.

TPU mapping
-----------
The FPGA "RAM read per chunk" becomes a *row gather* from a VMEM-resident
``(entries, p_block)`` tile: the one random-access pattern the TPU memory
system supports at full width (it is the embedding-lookup pattern).  The
grid is ``(batch_tiles, out_tiles, chunk_tiles)``; chunk tiles revisit the
output block and accumulate, so arbitrarily large layers stream through a
fixed VMEM budget:

  VMEM per step = kb * E * pb * 4   (tables)
                + bb * n * kb * 4   (codes)
                + bb * pb * 4       (accumulator)

Block sizes are chosen so this stays under ~4 MiB (cf. ``ops.py``).  The
plane loop is a ``fori_loop`` (n <= 16); the chunk loop is unrolled over the
chunk tile.  All accumulation is fp32 regardless of the table dtype — narrow
(int8/int16) tables are widened per gathered row, their dequant scale folded
into ``scales`` by the caller — matching the paper's full-precision-output
claim.

``shift_bits > 0`` selects the ``bitplane_shift`` contract: the code's low
``shift_bits`` index the (tiny, exponent-free) table and its high bits carry
the element's fp16 exponent, applied to the gathered row as
``2**(max(e,1)-25)`` — the barrel shift of the mode's name.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_row(tables2d, code, shift_bits: int):
    """(E, pb) table + (bb,) codes -> (bb, pb) rows, sigma-scaled when the
    codes carry an exponent in their high bits (bitplane_shift)."""
    if shift_bits:
        idx = code & (tables2d.shape[0] - 1)
        rows = jnp.take(tables2d, idx, axis=0).astype(jnp.float32)
        sig = jnp.exp2(jnp.maximum(code >> shift_bits, 1).astype(jnp.float32) - 25.0)
        return rows * sig[:, None]
    return jnp.take(tables2d, code, axis=0).astype(jnp.float32)


def _kernel(
    codes_ref,
    tables_ref,
    scales_ref,
    out_ref,
    *,
    block_k: int,
    planes: int,
    shift_bits: int,
):
    """One (batch, out, chunk) grid step.

    codes_ref : (bb, n, kb) int32         VMEM
    tables_ref: (kb, E, pb) f32/bf16/int8 VMEM
    scales_ref: (n, 1) f32                VMEM (2-D for TPU layout friendliness)
    out_ref   : (bb, pb) f32              VMEM (revisited across chunk tiles)
    """
    kt = pl.program_id(2)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def plane_body(j, acc):
        plane = jnp.zeros(out_ref.shape, jnp.float32)
        for c in range(block_k):  # static unroll over the chunk tile
            idx = codes_ref[:, j, c]  # (bb,) int32
            plane = plane + _gather_row(tables_ref[c], idx, shift_bits)
        return acc + scales_ref[j, 0] * plane

    acc = jax.lax.fori_loop(
        0, planes, plane_body, jnp.zeros(out_ref.shape, jnp.float32)
    )
    out_ref[...] += acc


def _grouped_kernel(
    codes_ref,
    tables_ref,
    scales_ref,
    out_ref,
    *,
    block_k: int,
    planes: int,
    shift_bits: int,
):
    """One (group, batch, out, chunk) grid step.

    The codes block is *shared* across the group dimension — the fused
    projections all read the same packed input — so revisiting it per group
    costs no extra packing, only the per-group table tile changes.

    codes_ref : (bb, n, kb) int32       VMEM
    tables_ref: (1, kb, E, pb) f32/bf16 VMEM (leading 1 = this group)
    scales_ref: (n, 1) f32              VMEM
    out_ref   : (1, bb, pb) f32         VMEM (revisited across chunk tiles)
    """
    kt = pl.program_id(3)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def plane_body(j, acc):
        plane = jnp.zeros(out_ref.shape[1:], jnp.float32)
        for c in range(block_k):  # static unroll over the chunk tile
            idx = codes_ref[:, j, c]  # (bb,) int32
            plane = plane + _gather_row(tables_ref[0, c], idx, shift_bits)
        return acc + scales_ref[j, 0] * plane

    acc = jax.lax.fori_loop(
        0, planes, plane_body, jnp.zeros(out_ref.shape[1:], jnp.float32)
    )
    out_ref[0] += acc


def _experts_kernel(
    offsets_ref,  # (E + 1,) int32 scalar-prefetch: group start offsets
    codes_ref,
    tables_ref,
    scales_ref,
    out_ref,
    *,
    block_b: int,
    block_k: int,
    planes: int,
    shift_bits: int,
):
    """One (group, token, out, expert, chunk) grid step.

    Tokens arrive SORTED by expert (the ``ragged_dot`` layout), so expert
    ``e`` owns the contiguous row range ``[offsets[e], offsets[e+1])``.  The
    grid walks every (token block, expert) pair; blocks outside the expert's
    row range skip the gather entirely (``pl.when``), so compute scales with
    the actual group occupancy — only the table-tile DMA is dense.  Rows a
    block shares with a neighbouring expert are masked before accumulation.

    codes_ref  : (bb, n, kb) int32        VMEM (shared across experts/groups)
    tables_ref : (1, 1, kb, En, pb)       VMEM (this expert+group's tiles)
    scales_ref : (n, 1) f32               VMEM
    out_ref    : (1, bb, pb) f32          VMEM (revisited across (e, chunk))
    """
    bt, e, kt = pl.program_id(1), pl.program_id(3), pl.program_id(4)

    @pl.when((e == 0) & (kt == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    start, end = offsets_ref[e], offsets_ref[e + 1]
    row0 = bt * block_b

    @pl.when((start < row0 + block_b) & (end > row0))
    def _compute():
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_b, 1), 0)
        live = (rows >= start) & (rows < end)  # (bb, 1)

        def plane_body(j, acc):
            plane = jnp.zeros(out_ref.shape[1:], jnp.float32)
            for c in range(block_k):  # static unroll over the chunk tile
                idx = codes_ref[:, j, c]  # (bb,) int32
                plane = plane + _gather_row(tables_ref[0, 0, c], idx, shift_bits)
            return acc + scales_ref[j, 0] * plane

        acc = jax.lax.fori_loop(
            0, planes, plane_body, jnp.zeros(out_ref.shape[1:], jnp.float32)
        )
        out_ref[0] += jnp.where(live, acc, 0.0)


def lut_affine_experts_pallas(
    offsets: jax.Array,  # (E + 1,) int32 cumulative group offsets
    codes: jax.Array,  # (T, n, k) int32, tokens sorted by expert
    tables: jax.Array,  # (E, G, k, En, p) pre-stacked expert tables
    scales: jax.Array,  # (n,) f32
    *,
    block_b: int,
    block_p: int,
    block_k: int,
    interpret: bool,
    shift_bits: int = 0,
) -> jax.Array:
    """Ragged (MoE expert) LUT affine: every token row against its own
    expert's pre-stacked tables, all ``G`` fused projections of the stack in
    the same grid.  ``offsets`` is scalar-prefetched (SMEM) so the row-range
    test runs before any table tile is touched."""
    T, n, k = codes.shape
    E, G, k2, En, p = tables.shape
    assert k == k2, (k, k2)
    assert offsets.shape == (E + 1,), offsets.shape
    assert T % block_b == 0 and p % block_p == 0 and k % block_k == 0
    grid = (G, T // block_b, p // block_p, E, k // block_k)

    kernel = functools.partial(
        _experts_kernel,
        block_b=block_b,
        block_k=block_k,
        planes=n,
        shift_bits=shift_bits,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, block_k), lambda g, b, q, e, c, offs: (b, 0, c)),
            pl.BlockSpec(
                (1, 1, block_k, En, block_p),
                lambda g, b, q, e, c, offs: (e, g, c, 0, q),
            ),
            pl.BlockSpec((n, 1), lambda g, b, q, e, c, offs: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_b, block_p), lambda g, b, q, e, c, offs: (g, b, q)
        ),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, T, p), jnp.float32),
        interpret=interpret,
    )(
        offsets.astype(jnp.int32),
        codes,
        tables,
        scales.reshape(n, 1).astype(jnp.float32),
    )


def lut_affine_grouped_pallas(
    codes: jax.Array,  # (B, n, k) int32, shared by the whole group
    tables: jax.Array,  # (G, k, E, p)
    scales: jax.Array,  # (n,) f32
    *,
    block_b: int,
    block_p: int,
    block_k: int,
    interpret: bool,
    shift_bits: int = 0,
) -> jax.Array:
    """All ``G`` same-shape projections of one decode step in a single grid:
    one Pallas dispatch instead of ``G`` (QKV / gate-up fusion)."""
    B, n, k = codes.shape
    G, k2, E, p = tables.shape
    assert k == k2, (k, k2)
    assert B % block_b == 0 and p % block_p == 0 and k % block_k == 0
    grid = (G, B // block_b, p // block_p, k // block_k)

    kernel = functools.partial(
        _grouped_kernel, block_k=block_k, planes=n, shift_bits=shift_bits
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, block_k), lambda g, b, q, c: (b, 0, c)),
            pl.BlockSpec((1, block_k, E, block_p), lambda g, b, q, c: (g, c, 0, q)),
            pl.BlockSpec((n, 1), lambda g, b, q, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_p), lambda g, b, q, c: (g, b, q)),
        out_shape=jax.ShapeDtypeStruct((G, B, p), jnp.float32),
        interpret=interpret,
    )(codes, tables, scales.reshape(n, 1).astype(jnp.float32))


def lut_affine_pallas(
    codes: jax.Array,  # (B, n, k) int32
    tables: jax.Array,  # (k, E, p)
    scales: jax.Array,  # (n,) f32
    *,
    block_b: int,
    block_p: int,
    block_k: int,
    interpret: bool,
    shift_bits: int = 0,
) -> jax.Array:
    B, n, k = codes.shape
    k2, E, p = tables.shape
    assert k == k2, (k, k2)
    assert B % block_b == 0 and p % block_p == 0 and k % block_k == 0
    grid = (B // block_b, p // block_p, k // block_k)

    kernel = functools.partial(
        _kernel, block_k=block_k, planes=n, shift_bits=shift_bits
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, block_k), lambda b, q, c: (b, 0, c)),
            pl.BlockSpec((block_k, E, block_p), lambda b, q, c: (c, 0, q)),
            pl.BlockSpec((n, 1), lambda b, q, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_p), lambda b, q, c: (b, q)),
        out_shape=jax.ShapeDtypeStruct((B, p), jnp.float32),
        interpret=interpret,
    )(codes, tables, scales.reshape(n, 1).astype(jnp.float32))
