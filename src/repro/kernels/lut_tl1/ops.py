"""Jit'd public wrappers around the TL1 Pallas kernels.

Handles padding to block multiples, block-size selection under the VMEM
budget, dequantization (per-token activation scale x ternary weight scale),
bias, and arbitrary leading batch dims.  Input is the flat padded code
vector ``repro.core.lut_tl1.quantize_acts`` produces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    ceil_to,
    check_acc_contract,
    default_interpret,
    pad_axis,
)
from repro.kernels.lut_tl1.lut_tl1 import lut_tl1_grouped_pallas, lut_tl1_pallas

_VMEM_BUDGET = 4 * 2**20  # bytes of live blocks per grid step


def _pick_blocks(B: int, kb: int, p: int, G: int = 1):
    """Block sizes keeping live tiles under ``_VMEM_BUDGET``.

    The packed-index tile is ``G * kb_block * p_block`` BYTES (uint8) and
    the activation tile ``bb * 4 * kb_block * 4`` — both tiny next to the
    weight family's ``entries``-wide tables, so block_k usually reaches the
    whole packed axis.
    """
    block_p = min(ceil_to(p, 128), 512)
    block_b = min(ceil_to(B, 8), 128)
    per_k = G * block_p + block_b * 16  # bytes per unit of block_k
    max_kb = max(1, _VMEM_BUDGET // per_k)
    block_k = 1
    while block_k * 2 <= min(max_kb, kb):
        block_k *= 2
    return block_b, block_p, block_k


def _acts3(acts: jax.Array, kb: int):
    """(..., 4*kb) flat codes -> (B, 4, kb) kernel tile layout + lead dims."""
    *lead, q4 = acts.shape
    assert q4 == 4 * kb, (q4, kb)
    B = 1
    for d in lead:
        B *= d
    return jnp.swapaxes(acts.reshape(B, kb, 4), 1, 2), lead, B


def _dequant(out, act_scale, scale, bias):
    out = out.astype(jnp.float32)
    if act_scale is not None:
        out = out * act_scale
    if scale is not None:
        out = out * scale
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return out


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_p", "block_k", "interpret")
)
def _lut_tl1_padded(acts, tables, block_b, block_p, block_k, interpret):
    return lut_tl1_pallas(
        acts,
        tables,
        block_b=block_b,
        block_p=block_p,
        block_k=block_k,
        interpret=interpret,
    )


def lut_tl1(
    acts: jax.Array,  # (..., 4*kb) int32 codes (or f32, exact variant)
    tables: jax.Array,  # (kb, p) uint8 packed base-3 indices
    act_scale: jax.Array | None = None,  # (..., 1) per-token dequant scale
    scale: jax.Array | None = None,  # ternary weight scale
    bias: jax.Array | None = None,  # (p,)
    *,
    interpret: bool | None = None,
    blocks: tuple[int, int, int] | None = None,
    plan=None,
) -> jax.Array:
    """out[..., :] = act_scale * scale * sum_c lut[c, widx[c, :]] + bias

    ``blocks`` overrides the static ``_pick_blocks`` heuristic with autotuned
    ``(block_b, block_p, block_k)`` tile sizes (block_k in packed bytes);
    ``plan`` (a ``TL1Plan``) asserts the accumulator contract at trace time
    when it carries a proved ``max_abs_acc``."""
    if plan is not None:
        check_acc_contract(
            "lut_tl1",
            plan,
            "int32" if jnp.issubdtype(acts.dtype, jnp.integer) else "float32",
        )
    if interpret is None:
        interpret = default_interpret()
    kb, p = tables.shape
    acts3, lead, B = _acts3(acts, kb)

    block_b, block_p, block_k = blocks or _pick_blocks(B, kb, p)
    Bp, pp, kp = ceil_to(B, block_b), ceil_to(p, block_p), ceil_to(kb, block_k)
    # padded chunk rows meet zero-padded activation codes -> every LUT entry
    # they can index is 0; padded p columns are sliced off below
    acts3 = pad_axis(pad_axis(acts3, 0, Bp), 2, kp)
    tables_p = pad_axis(pad_axis(tables, 0, kp), 1, pp)

    out = _lut_tl1_padded(acts3, tables_p, block_b, block_p, block_k, interpret)
    out = out[:B, :p].reshape(*lead, p)
    if act_scale is not None:
        act_scale = act_scale.reshape(*lead, 1)
    return _dequant(out, act_scale, scale, bias)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_p", "block_k", "interpret")
)
def _lut_tl1_grouped_padded(acts, tables, block_b, block_p, block_k, interpret):
    return lut_tl1_grouped_pallas(
        acts,
        tables,
        block_b=block_b,
        block_p=block_p,
        block_k=block_k,
        interpret=interpret,
    )


def lut_tl1_grouped(
    acts: jax.Array,  # (..., 4*kb) — one quantized input for the group
    tables: jax.Array,  # (G, kb, p) uint8 — pre-stacked same-shape projections
    act_scale: jax.Array | None = None,  # (..., 1)
    scale: jax.Array | None = None,  # (G,) per-member ternary scales
    biases: jax.Array | None = None,  # (G, p)
    *,
    interpret: bool | None = None,
    blocks: tuple[int, int, int] | None = None,
    plan=None,
) -> jax.Array:
    """Fused batched decode path: ``out[g] = lut_tl1(acts, tables[g],
    act_scale, scale[g]) (+ biases[g])`` for all ``G`` projections in ONE
    Pallas grid.  ``tables`` is exactly the leaf a TL1-converted
    ``core.convert.LUTGroup`` stores."""
    if plan is not None:
        check_acc_contract(
            "lut_tl1_grouped",
            plan,
            "int32" if jnp.issubdtype(acts.dtype, jnp.integer) else "float32",
        )
    if interpret is None:
        interpret = default_interpret()
    G, kb, p = tables.shape
    acts3, lead, B = _acts3(acts, kb)

    block_b, block_p, block_k = blocks or _pick_blocks(B, kb, p, G=G)
    Bp, pp, kp = ceil_to(B, block_b), ceil_to(p, block_p), ceil_to(kb, block_k)
    acts3 = pad_axis(pad_axis(acts3, 0, Bp), 2, kp)
    tables_p = pad_axis(pad_axis(tables, 1, kp), 2, pp)

    out = _lut_tl1_grouped_padded(
        acts3, tables_p, block_b, block_p, block_k, interpret
    )
    out = out[:, :B, :p].reshape(G, *lead, p)
    if act_scale is not None:
        act_scale = act_scale.reshape(*lead, 1)
    if scale is not None:
        scale = scale.reshape(G, *([1] * (out.ndim - 1)))
    if biases is not None:
        biases = biases.reshape(G, *([1] * (out.ndim - 2)), p)
    return _dequant(out, act_scale, scale, biases)
