"""Pallas TPU kernels for the TL1 activation-side LUT family.

Computes ``out[b, :] = sum_c lut_b[c, widx[c, :]]`` where ``widx`` are the
base-3 ternary weight-pair indices packed two-per-byte at conversion time
(``repro.core.lut_tl1.pack_ternary``) and ``lut_b`` is the per-token 9-entry
activation LUT built *inside the kernel* each step: entry ``i`` of pair
chunk ``c`` is ``(i//3 - 1) * a[2c] + (i%3 - 1) * a[2c+1]`` — nine sums /
differences of two activations, adds only.

TPU mapping
-----------
Same shape discipline as ``lut_affine``: grid ``(batch_tiles, out_tiles,
packed_chunk_tiles)`` with the output block revisited and accumulated across
chunk tiles.  Per step the table tile is ``(kb_block, p_block)`` **bytes**
(the packed indices), the activation tile is ``(bb, 4, kb_block)`` codes,
and each packed byte unpacks to two nibble indices gathering from two
freshly built ``(bb, 9)`` LUTs.  The gather is a 9-wide row lookup — the
inverse of the weight family's ``(entries, p)`` row gather: here the table
axis is tiny and the *index* operand is weight-shaped.

LUT entries are int16, accumulation int32.  Both are *proved* per-plan
contracts, not folklore: ``repro.audit.ranges.layer_range_cert`` certifies
``|entry| <= 2*qa`` and ``|acc| <= 2*qa*num_chunks`` (``qa =
2**(act_bits-1) - 1``), the planner stamps the bound on each ``TL1Plan``
(``max_abs_acc`` / ``acc_dtype``) and rejects plans it cannot prove safe,
and the wrappers in ``ops.py`` re-assert the contract at trace time via
``repro.kernels.common.check_acc_contract``.  With fp32 activation codes
(``act_bits=None``) entries and accumulator stay fp32 and the kernel is
exact w.r.t. a dense matmul over the ternary weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _acc_dtypes(acts_dtype):
    if jnp.issubdtype(acts_dtype, jnp.integer):
        return jnp.int16, jnp.int32
    return jnp.float32, jnp.float32


def _pair_lut(a0, a1, entry_dtype):
    """(bb,) x2 activation codes -> (bb, 9) LUT, adds only.

    Entry ``i = (s0+1)*3 + (s1+1)`` holds ``s0*a0 + s1*a1``.
    """
    z = jnp.zeros_like(a0)
    lut = jnp.stack(
        [-a0 - a1, -a0, a1 - a0, -a1, z, a1, a0 - a1, a0, a0 + a1], axis=1
    )
    return lut.astype(entry_dtype)


def _accum_block(acts_ref, tables2d, block_k: int, shape, acts_at):
    """Shared accumulate over one packed-chunk tile.

    ``tables2d``: (kb, pb) uint8; ``acts_at(j, c)``: code of element 4c+j.
    """
    entry_dtype, acc_dtype = _acc_dtypes(acts_ref.dtype)
    acc = jnp.zeros(shape, acc_dtype)
    for c in range(block_k):  # static unroll over the packed-chunk tile
        w = tables2d[c].astype(jnp.int32)  # (pb,) packed byte
        lo, hi = w & 15, w >> 4
        lut_lo = _pair_lut(acts_at(0, c), acts_at(1, c), entry_dtype)
        lut_hi = _pair_lut(acts_at(2, c), acts_at(3, c), entry_dtype)
        acc = acc + jnp.take(lut_lo, lo, axis=1).astype(acc_dtype)
        acc = acc + jnp.take(lut_hi, hi, axis=1).astype(acc_dtype)
    return acc


def _kernel(acts_ref, tables_ref, out_ref, *, block_k: int):
    """One (batch, out, packed-chunk) grid step.

    acts_ref  : (bb, 4, kb) int32/f32 VMEM — activation codes, element
                ``4c + j`` at ``[:, j, c]`` (the codes-tile layout of
                ``lut_affine`` with the plane axis reused for the 4 byte slots)
    tables_ref: (kb, pb) uint8 VMEM — packed base-3 weight-pair indices
    out_ref   : (bb, pb) int32/f32 VMEM (revisited across chunk tiles)
    """
    kt = pl.program_id(2)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _accum_block(
        acts_ref,
        tables_ref,
        block_k,
        out_ref.shape,
        lambda j, c: acts_ref[:, j, c],
    )


def _grouped_kernel(acts_ref, tables_ref, out_ref, *, block_k: int):
    """One (group, batch, out, packed-chunk) grid step.

    The activation tile is shared across the group dimension — the fused
    projections all quantize the same input once — only the per-group
    packed-index tile changes.

    acts_ref  : (bb, 4, kb)    VMEM
    tables_ref: (1, kb, pb) u8 VMEM (leading 1 = this group)
    out_ref   : (1, bb, pb)    VMEM (revisited across chunk tiles)
    """
    kt = pl.program_id(3)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0] += _accum_block(
        acts_ref,
        tables_ref[0],
        block_k,
        out_ref.shape[1:],
        lambda j, c: acts_ref[:, j, c],
    )


def lut_tl1_pallas(
    acts: jax.Array,  # (B, 4, kb) int32 (or f32 for the exact variant)
    tables: jax.Array,  # (kb, p) uint8 packed indices
    *,
    block_b: int,
    block_p: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    B, four, kb = acts.shape
    kb2, p = tables.shape
    assert four == 4 and kb == kb2, (acts.shape, tables.shape)
    assert B % block_b == 0 and p % block_p == 0 and kb % block_k == 0
    grid = (B // block_b, p // block_p, kb // block_k)
    _, acc_dtype = _acc_dtypes(acts.dtype)

    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 4, block_k), lambda b, q, c: (b, 0, c)),
            pl.BlockSpec((block_k, block_p), lambda b, q, c: (c, q)),
        ],
        out_specs=pl.BlockSpec((block_b, block_p), lambda b, q, c: (b, q)),
        out_shape=jax.ShapeDtypeStruct((B, p), acc_dtype),
        interpret=interpret,
    )(acts, tables)


def lut_tl1_grouped_pallas(
    acts: jax.Array,  # (B, 4, kb) — one quantized input for the group
    tables: jax.Array,  # (G, kb, p) uint8
    *,
    block_b: int,
    block_p: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    """All ``G`` same-shape TL1 projections of one decode step in a single
    grid — one dispatch per step for a whole QKV or gate/up group."""
    B, four, kb = acts.shape
    G, kb2, p = tables.shape
    assert four == 4 and kb == kb2, (acts.shape, tables.shape)
    assert B % block_b == 0 and p % block_p == 0 and kb % block_k == 0
    grid = (G, B // block_b, p // block_p, kb // block_k)
    _, acc_dtype = _acc_dtypes(acts.dtype)

    return pl.pallas_call(
        functools.partial(_grouped_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 4, block_k), lambda g, b, q, c: (b, 0, c)),
            pl.BlockSpec((1, block_k, block_p), lambda g, b, q, c: (g, c, q)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_p), lambda g, b, q, c: (g, b, q)),
        out_shape=jax.ShapeDtypeStruct((G, B, p), acc_dtype),
        interpret=interpret,
    )(acts, tables)
