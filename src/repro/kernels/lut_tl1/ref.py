"""Pure-jnp oracle for the TL1 kernel contract (tested against Pallas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lut_tl1 import build_act_lut, unpack_indices


def lut_tl1_ref(acts: jax.Array, tables: jax.Array) -> jax.Array:
    """acts (B, 4, kb) int32/f32, tables (kb, p) uint8 -> (B, p) int32/f32.

    Same contract as :func:`repro.kernels.lut_tl1.ops.lut_tl1`'s inner
    kernel: raw accumulate, no scales/bias.
    """
    B, four, kb = acts.shape
    assert four == 4 and tables.shape[0] == kb, (acts.shape, tables.shape)
    flat = jnp.swapaxes(acts, 1, 2).reshape(B, 4 * kb)  # element 4c+j order
    lut = build_act_lut(flat)  # (B, 2kb, 9)
    idx = unpack_indices(tables)  # (2kb, p)
    p = idx.shape[-1]
    g = jnp.take_along_axis(lut, jnp.broadcast_to(idx, lut.shape[:-1] + (p,)), axis=-1)
    acc_dtype = jnp.int32 if jnp.issubdtype(g.dtype, jnp.integer) else jnp.float32
    return jnp.sum(g.astype(acc_dtype), axis=-2)


def lut_tl1_grouped_ref(acts: jax.Array, tables: jax.Array) -> jax.Array:
    """acts (B, 4, kb), tables (G, kb, p) -> (G, B, p)."""
    return jax.vmap(lambda t: lut_tl1_ref(acts, t))(tables)
