"""Public serving API.

This package is the supported surface for serving: import from
``repro.serve``, not from the implementation modules.

Engine / generation:
  :class:`BatchingEngine` — fixed-slot continuous batching over a
  device-resident (optionally paged, prefix-shared) cache
  :class:`Request`, :func:`generate`, :class:`SampleCfg`
Cache construction and contracts:
  :func:`make_cache`, :func:`abstract_cache`, :func:`cache_specs`,
  :func:`advance_meta` -> :class:`CacheWrite`, :class:`CacheOverflowError`
Paged-mode internals exposed for instrumentation:
  :class:`PageAllocator` (``engine.alloc``), :class:`PagePoolExhausted`
"""
from repro.serve._cache import (
    CacheOverflowError,
    CacheWrite,
    advance_meta,
    cache_specs,
    update_kv_cache,
    update_mla_cache,
)
from repro.serve._engine import (
    BatchingEngine,
    Request,
    SampleCfg,
    abstract_cache,
    generate,
    make_cache,
    make_decode_step,
    make_prefill_step,
)
from repro.serve._paging import PageAllocator, PagePoolExhausted

__all__ = [
    "BatchingEngine",
    "CacheOverflowError",
    "CacheWrite",
    "PageAllocator",
    "PagePoolExhausted",
    "Request",
    "SampleCfg",
    "abstract_cache",
    "advance_meta",
    "cache_specs",
    "generate",
    "make_cache",
    "make_decode_step",
    "make_prefill_step",
    "update_kv_cache",
    "update_mla_cache",
]
