"""Serving engine: a device-resident batched scheduler over slot caches.

The TableNet integration is first-class: pass ``lut_params`` (from
``core.convert.convert_params``, ideally per-layer-planned via
``core.planner.plan_model``) and every converted projection executes via
the paper's LUT path — ``ExecCfg(use_pallas=True)`` routes through the
Pallas kernel on real devices, the jnp oracle otherwise, and
``ExecCfg(lut_grouped=True)`` additionally fuses same-shape projections
(QKV, gate/up) into one grouped dispatch per decode step.  The scheduler
is agnostic to all of it: both steps inherit the choice from the ``Ctx``
they are built with, so the grouped pre-stacked fast path rides through
unchanged.

Scheduler architecture (``BatchingEngine``):

* **Device-resident slot state.**  The cache carries, besides the KV ring,
  per-slot ``slot_active`` / ``slot_remaining`` / ``slot_key`` /
  ``next_tok`` / ``overflow`` leaves.  Both the prefill and the decode
  step are jitted functions ``(params, cache, ...) -> (cache, packed)``
  whose cache argument is **donated** — steady-state decode does zero
  full-cache allocations (XLA aliases every cache buffer in place) and no
  host-side cache surgery ever happens (the old ``_splice_cache``
  full-cache copies are gone).
* **Fused on-device sampling.**  ``SampleCfg`` (greedy / temperature /
  top-k) executes inside the jitted steps.  Non-greedy draws use
  ``fold_in(slot_key, index)`` — ``slot_key`` is derived from the request
  uid at admission and ``index`` is the slot's write offset — so a sampled
  stream is a pure function of (engine seed, uid, position) and identical
  under batched-admit and per-slot-admit schedules.
* **Batched multi-slot prefill.**  Admission right-pads up to
  ``num_slots`` queued prompts into one (num_slots, S_bucket) batch and
  runs ONE prefill that writes each prompt directly into its slot via the
  one-hot slot machinery (``token_mask`` masks pad positions and
  mid-decode slots).  ``admit="per-slot"`` admits one request per prefill
  call instead — same compiled step, more calls (the measured baseline in
  ``benchmarks/serving.py``).
* **One small readback per step.**  Each step returns a packed (B, 3)
  int32 array ``[token, done, overflow]``; ``step()`` reads it back once
  (steady-state decode: exactly one host readback; an admission round
  adds one for its prefill).  Blocking per-slot ``int(...)`` scalar syncs
  are gone.

Paged mode (``page_size=``): the cache stores K/V in fixed-size pages
behind a slot→page table (``repro.serve._cache``); a host-side
:class:`~repro.serve._paging.PageAllocator` maps pages on demand at
admission and before each decode step, and frees them (refcounted) on
retire.  Admission consults a prompt-prefix registry: a request whose
leading full pages match an earlier prompt maps those pages read-only and
prefills only the divergent tail — with at most one copy-on-write page
duplication (executed in-graph at the start of the prefill step) when the
whole prompt matched.  Requests whose prefix would match pages written in
the *same* admission round are deferred one round so they share instead of
re-prefilling.  The donated-cache / one-readback-per-step discipline is
unchanged: the host only uploads the small (B, max_pages) table when it
changes; ``engine.prefill_tokens`` counts actually-prefilled tokens (tails
only, under sharing) and ``engine.alloc.pages_in_use`` exposes physical
page occupancy.

Overflow policy: requests that cannot fit (``prompt + max_new - 1 >
max_len``) raise :class:`CacheOverflowError` at ``submit()``; the packed
``overflow`` column (accumulated by the cache layer whenever a write slot
would fall past ``max_len`` or land in an unmapped page) is checked on
every readback as a backstop, so overflowing tokens can never be silently
dropped.  In paged mode, pool exhaustion defers admission while any slot
is active (retires will free pages) and raises ``CacheOverflowError`` when
nothing can ever free one.

``decode_step`` is what the decode_32k / long_500k dry-run cells lower: one
new token against a seq_len-deep cache, caches seq-sharded over the model
axis (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, SampleCfg, sample_tokens
from repro.models.model import model_forward
from repro.models.params import abstract_params, init_params
from repro.serve._cache import CacheOverflowError, cache_specs, copy_pages
from repro.serve._paging import PageAllocator, PagePoolExhausted, _prefix_key

__all__ = [
    "BatchingEngine",
    "CacheOverflowError",
    "Request",
    "SampleCfg",
    "abstract_cache",
    "generate",
    "make_cache",
    "make_decode_step",
    "make_prefill_step",
]

# families whose caches support slot-targeted masked prefill writes
_ENGINE_FAMILIES = ("dense", "moe", "vlm")


def make_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    ctx: Ctx,
    dtype=jnp.bfloat16,
    page_size: int | None = None,
    num_pages: int | None = None,
    page_table: str = "identity",
):
    """Materialize a fresh cache.  With ``page_size``, K/V storage is paged
    (see ``repro.serve._cache``): ``page_table="identity"`` statically maps
    slot b's group g to page ``b * max_pages + g`` — a standalone paged
    cache that behaves exactly like the dense rectangle (``generate`` uses
    this); ``page_table="empty"`` starts fully unmapped for an allocator
    (``BatchingEngine``) to fill."""
    specs = cache_specs(
        cfg, batch, max_len, page_size=page_size, num_pages=num_pages
    )
    cache = init_params(specs, jax.random.PRNGKey(0), default_dtype=dtype)
    if page_size is not None:
        max_pages = cache["pos"].shape[1] // page_size
        if page_table == "identity":
            n_phys = cache["layers"][next(iter(cache["layers"]))].shape[1]
            if n_phys < batch * max_pages:
                raise ValueError(
                    f"identity page table needs {batch * max_pages} pages; "
                    f"pool has {n_phys}"
                )
            cache["page_table"] = jnp.arange(
                batch * max_pages, dtype=jnp.int32
            ).reshape(batch, max_pages)
        elif page_table == "empty":
            cache["page_table"] = jnp.full((batch, max_pages), -1, jnp.int32)
        else:
            raise ValueError(
                f"page_table must be 'identity' or 'empty': {page_table!r}"
            )
    return cache


def abstract_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    ctx: Ctx,
    dtype=jnp.bfloat16,
    page_size: int | None = None,
    num_pages: int | None = None,
):
    specs = cache_specs(
        cfg, batch, max_len, page_size=page_size, num_pages=num_pages
    )
    return abstract_params(
        specs,
        default_dtype=dtype,
        sharding_fn=(
            ctx.shard.param_sharding if ctx.shard.mesh is not None else None
        ),
    )


def _serve_ctx(ctx: Ctx) -> Ctx:
    return dataclasses.replace(ctx, ex=dataclasses.replace(ctx.ex, remat="none"))


def _slot_keys(cache: dict) -> jax.Array:
    """Per-slot sampling keys at the current write offsets (B, 2) uint32."""
    return jax.vmap(jax.random.fold_in)(cache["slot_key"], cache["index"])


def make_prefill_step(ctx: Ctx) -> Callable:
    """(params, inputs, cache) -> (last-token logits, filled cache)."""
    sctx = _serve_ctx(ctx)

    def prefill(params, inputs, cache):
        logits, cache, _ = model_forward(params, inputs, sctx, cache=cache)
        return logits[:, -1:], cache

    return prefill


def make_decode_step(ctx: Ctx, sample: SampleCfg | None = None) -> Callable:
    """(params, cache, tokens (B,1)) -> (next tokens (B,1), logits, cache).

    With a non-greedy ``sample``, the cache must carry a ``slot_key`` leaf
    ((B, 2) uint32 per-row PRNG keys); sampling runs fused on device.
    """
    scfg = sample or SampleCfg()
    sctx = _serve_ctx(ctx)

    def decode(params, cache, tokens):
        logits, cache, _ = model_forward(
            params, {"tokens": tokens}, sctx, cache=cache
        )
        keys = _slot_keys(cache) if scfg.mode != "greedy" else None
        nxt = sample_tokens(logits[:, -1], scfg, keys)[:, None]
        return nxt, logits, cache

    return decode


def generate(
    params,
    ctx: Ctx,
    prompts: jax.Array,
    max_new: int,
    max_len: int | None = None,
    eos_id: Optional[int] = None,
    enc_embeds: jax.Array | None = None,
    embeds: jax.Array | None = None,
    sample: SampleCfg | None = None,
    key: jax.Array | None = None,
    page_size: int | None = None,
) -> jax.Array:
    """Reference generation loop used by tests/examples.

    Semantics are aligned with :class:`BatchingEngine`: each row stops at
    its first ``eos_id`` token (the EOS itself is emitted); since the
    return value is rectangular (B, max_new), positions past a row's EOS
    are padded with ``eos_id``.  Non-greedy ``sample`` draws with
    ``fold_in(fold_in(key, row), position)`` per row.  Raises
    :class:`CacheOverflowError` up front when ``prompt + max_new - 1``
    writes cannot fit in ``max_len`` (a non-windowed cache would silently
    drop the overflowing tokens otherwise — the pre-PR4 bug).  With
    ``page_size``, K/V storage is paged behind an identity-mapped page
    table — same semantics, paged layout.
    """
    B, S = prompts.shape
    scfg = sample or SampleCfg()
    pre = S + (embeds.shape[1] if embeds is not None else 0)
    T = max_len or (pre + max_new)
    if ctx.cfg.sliding_window is None and pre + max_new - 1 > T:
        raise CacheOverflowError(
            f"prompt ({pre} tokens) + max_new ({max_new}) needs "
            f"{pre + max_new - 1} cache slots but max_len is {T}; raise "
            "max_len — overflowing one-hot writes would drop tokens"
        )
    cache = make_cache(ctx.cfg, B, T, ctx, page_size=page_size)
    if scfg.mode != "greedy":
        base = key if key is not None else jax.random.PRNGKey(0)
        cache["slot_key"] = jax.vmap(
            lambda r: jax.random.fold_in(base, r)
        )(jnp.arange(B, dtype=jnp.int32))
    prefill = jax.jit(make_prefill_step(ctx), donate_argnums=(2,))
    decode = jax.jit(make_decode_step(ctx, scfg), donate_argnums=(1,))
    inputs = {"tokens": prompts}
    if enc_embeds is not None:
        inputs["enc_embeds"] = enc_embeds
    if embeds is not None:
        inputs["embeds"] = embeds
    logits, cache = prefill(params, inputs, cache)
    keys = _slot_keys(cache) if scfg.mode != "greedy" else None
    tok = sample_tokens(logits[:, -1], scfg, keys)[:, None]
    out = [tok]
    done = np.zeros((B,), bool)
    for _ in range(max_new - 1):
        if eos_id is not None:
            done = done | (np.asarray(tok[:, 0]) == eos_id)
            if done.all():
                break
        tok, _, cache = decode(params, cache, tok)
        if eos_id is not None:
            tok = jnp.where(jnp.asarray(done)[:, None], eos_id, tok)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    if toks.shape[1] < max_new:  # every row hit EOS early: pad rectangle
        pad = jnp.full((B, max_new - toks.shape[1]), eos_id, jnp.int32)
        toks = jnp.concatenate([toks, pad], axis=1)
    return toks


# ---------------------------------------------------------------------------
# Device-resident batched scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any  # (S,) int32
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@functools.lru_cache(maxsize=32)
def _engine_steps(
    ctx: Ctx, scfg: SampleCfg, eos_id: Optional[int], paged: bool = False
):
    """Compiled engine steps, shared across engine instances (lru-cached so
    repeated engine construction — benchmarks, tests — never recompiles).

    prefill: (params, cache, tokens, lens, admit, uids, max_news, base_key)
             -> (cache, packed); the paged variant takes three extra arrays
             (starts, copy_src, copy_dst): per-slot first-prefilled logical
             position (everything before it is mapped from shared pages)
             and at most one COW page duplication applied in-graph before
             the forward.
    decode:  (params, cache) -> (cache, packed)
    with packed (B, 3) int32 = [sampled token, done, overflow] — the single
    small array the host reads back per step.  Both donate their cache.
    """
    # force logits="all": the batched prefill gathers each slot's logits at
    # its own last REAL position (lens - 1); under logits="last" the model
    # would return only the right-padded final position's head — pad logits
    sctx = dataclasses.replace(
        ctx, ex=dataclasses.replace(ctx.ex, remat="none", logits="all")
    )

    def _sample(last, cache):
        keys = _slot_keys(cache) if scfg.mode != "greedy" else None
        return sample_tokens(last, scfg, keys)

    def _packed(tok, done, cache):
        return jnp.stack(
            [tok, done.astype(jnp.int32), cache["overflow"].astype(jnp.int32)],
            axis=1,
        )

    def _run_prefill(params, cache, tokens, lens, admit):
        """Shared tail: masked forward + per-slot last-real-token sampling."""
        S = tokens.shape[1]
        adm1 = admit[:, None]
        mask = (jnp.arange(S, dtype=jnp.int32)[None, :] < lens[:, None]) & adm1
        logits, cache, _ = model_forward(
            params, {"tokens": tokens, "token_mask": mask}, sctx, cache=cache
        )
        last = jnp.take_along_axis(
            logits, jnp.maximum(lens - 1, 0)[:, None, None], axis=1
        )[:, 0]
        tok = _sample(last, cache)
        eos_hit = (tok == eos_id) if eos_id is not None else jnp.zeros_like(admit)
        done = admit & (eos_hit | (cache["slot_remaining"] <= 0))
        cache = dict(
            cache,
            slot_active=(cache["slot_active"] | admit) & ~done,
            next_tok=jnp.where(adm1, tok[:, None], cache["next_tok"]),
        )
        return cache, _packed(tok, done, cache)

    def prefill(params, cache, tokens, lens, admit, uids, max_news, base_key):
        fresh_keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
        adm1 = admit[:, None]
        cache = dict(
            cache,
            index=jnp.where(admit, 0, cache["index"]),
            pos=jnp.where(adm1, 0, cache["pos"]),
            valid=cache["valid"] & ~adm1,
            overflow=cache["overflow"] & ~admit,
            slot_key=jnp.where(adm1, fresh_keys, cache["slot_key"]),
            slot_remaining=jnp.where(admit, max_news - 1, cache["slot_remaining"]),
        )
        return _run_prefill(params, cache, tokens, lens, admit)

    def prefill_paged(
        params, cache, tokens, lens, admit, uids, max_news, base_key,
        starts, copy_src, copy_dst,
    ):
        T = cache["pos"].shape[1]
        fresh_keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
        adm1 = admit[:, None]
        tpos = jnp.arange(T, dtype=jnp.int32)[None, :]
        shared = tpos < starts[:, None]  # slots mapped from the prefix registry
        # COW duplications first: the divergent tail below overwrites only
        # private copies, never pages other slots still reference
        layers = {
            name: copy_pages(leaf, copy_src, copy_dst)
            for name, leaf in cache["layers"].items()
        }
        cache = dict(
            cache,
            layers=layers,
            index=jnp.where(admit, starts, cache["index"]),
            # shared-prefix slots are valid with their absolute positions;
            # the tail is written by the masked forward below
            pos=jnp.where(adm1, jnp.where(shared, tpos, 0), cache["pos"]),
            valid=jnp.where(adm1, shared, cache["valid"]),
            overflow=cache["overflow"] & ~admit,
            slot_key=jnp.where(adm1, fresh_keys, cache["slot_key"]),
            slot_remaining=jnp.where(admit, max_news - 1, cache["slot_remaining"]),
        )
        return _run_prefill(params, cache, tokens, lens, admit)

    def decode(params, cache):
        active = cache["slot_active"]
        logits, cache, _ = model_forward(
            params,
            {"tokens": cache["next_tok"], "token_mask": active[:, None]},
            sctx,
            cache=cache,
        )
        tok = _sample(logits[:, -1], cache)
        remaining = cache["slot_remaining"] - active.astype(jnp.int32)
        eos_hit = (tok == eos_id) if eos_id is not None else jnp.zeros_like(active)
        done = active & (eos_hit | (remaining <= 0))
        cache = dict(
            cache,
            slot_remaining=remaining,
            slot_active=active & ~done,
            next_tok=jnp.where(active[:, None], tok[:, None], cache["next_tok"]),
        )
        return cache, _packed(tok, done, cache)

    return (
        jax.jit(prefill_paged if paged else prefill, donate_argnums=(1,)),
        jax.jit(decode, donate_argnums=(1,)),
    )


def _bucket(n: int, cap: int) -> int:
    """Right-pad prompts to a power-of-two bucket (bounds recompilation)."""
    b = 4
    while b < n:
        b *= 2
    return min(b, cap)


class BatchingEngine:
    """Fixed-slot continuous batching, fully device-resident: finished
    sequences are swapped out for queued requests between decode steps via
    batched masked prefill (see the module docstring for the scheduler
    architecture, paging/prefix-sharing, sampling determinism, readback and
    overflow contracts).
    """

    def __init__(
        self,
        params,
        ctx: Ctx,
        num_slots: int,
        max_len: int,
        eos_id: Optional[int] = None,
        sample: SampleCfg | None = None,
        seed: int = 0,
        admit: str = "batched",
        prefill_bucket: int | None = None,
        page_size: int | None = None,
        num_pages: int | None = None,
        share_prefixes: bool = True,
    ):
        if ctx.cfg.family not in _ENGINE_FAMILIES:
            raise NotImplementedError(
                f"BatchingEngine needs slot-targeted cache writes; family "
                f"{ctx.cfg.family!r} has recurrent/cross caches without them"
            )
        if admit not in ("batched", "per-slot"):
            raise ValueError(f"admit must be 'batched' or 'per-slot': {admit!r}")
        self.params, self.ctx = params, ctx
        self.num_slots, self.max_len = num_slots, max_len
        self.eos_id = eos_id
        self.sample = sample or SampleCfg()
        self.admit_mode = admit
        self.page_size = page_size
        self.queue: list[Request] = []
        self.slots: list[Optional[Request]] = [None] * num_slots
        self._windowed = ctx.cfg.sliding_window is not None
        if page_size is not None:
            self.cache = make_cache(
                ctx.cfg, num_slots, max_len, ctx,
                page_size=page_size, num_pages=num_pages, page_table="empty",
            )
            self._T = self.cache["pos"].shape[1]
            pages_per_slot = self._T // page_size
            self.alloc: Optional[PageAllocator] = PageAllocator(
                num_pages or num_slots * pages_per_slot,
                page_size,
                num_slots,
                pages_per_slot,
                # ring contents are position-dependent: never share them
                share=share_prefixes and not self._windowed,
            )
        else:
            self.cache = make_cache(ctx.cfg, num_slots, max_len, ctx)
            self._T = self.cache["pos"].shape[1]  # min(window, max_len) for SWA
            self.alloc = None
        self.prefill_bucket = prefill_bucket
        if prefill_bucket is not None and prefill_bucket > self._T:
            raise ValueError(
                f"prefill_bucket {prefill_bucket} exceeds cache capacity {self._T}"
            )
        self.cache.update(
            overflow=jnp.zeros((num_slots,), bool),
            slot_active=jnp.zeros((num_slots,), bool),
            slot_remaining=jnp.zeros((num_slots,), jnp.int32),
            slot_key=jnp.zeros((num_slots, 2), jnp.uint32),
            next_tok=jnp.zeros((num_slots, 1), jnp.int32),
        )
        self._base_key = jax.random.PRNGKey(seed)
        self._prefill, self._decode = _engine_steps(
            ctx, self.sample, eos_id, paged=page_size is not None
        )
        self.readbacks = 0  # host syncs: 1/decode step + 1/admission prefill
        self.prefill_tokens = 0  # tokens actually prefilled (tails only)
        self._slot_len = [0] * num_slots  # host mirror of per-slot index

    def submit(self, req: Request):
        plen = int(req.prompt.shape[0])
        if plen < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        cap = self.prefill_bucket or self._T
        if plen > cap:
            raise ValueError(
                f"request {req.uid}: prompt ({plen}) exceeds the prefill "
                f"capacity ({cap} tokens)"
            )
        if (
            self.ctx.cfg.sliding_window is None
            and plen + req.max_new - 1 > self.max_len
        ):
            raise CacheOverflowError(
                f"request {req.uid}: prompt ({plen}) + max_new ({req.max_new}) "
                f"needs {plen + req.max_new - 1} cache slots but max_len is "
                f"{self.max_len}; overflowing writes would drop tokens"
            )
        self.queue.append(req)

    def _check(self, packed) -> np.ndarray:
        """The ONE host readback per step; backstop overflow check."""
        arr = np.asarray(packed)
        self.readbacks += 1
        if arr[:, 2].any():
            raise CacheOverflowError(
                f"cache overflow flagged for slots {arr[:, 2].nonzero()[0].tolist()}"
            )
        return arr

    def _plan_batch(self, free: list[int], limit: int):
        """Pop up to ``limit`` admittable requests, assigning slots (and,
        when paged, page mappings).  Prefix-sharing candidates whose donor
        is being prefilled in this same round are deferred one round so
        they map its registered pages instead of re-prefilling."""
        placed: list[tuple[Request, int, Any]] = []
        pending: set[bytes] = set()
        while self.queue and len(placed) < limit:
            req = self.queue.pop(0)
            if req.max_new <= 0:
                req.done = True  # nothing requested; don't pay a prefill
                continue
            s = free[len(placed)]
            if self.alloc is None:
                placed.append((req, s, None))
                continue
            pnp = np.asarray(req.prompt, np.int32)
            keys = (
                [
                    _prefix_key(pnp, m * self.page_size)
                    for m in range(1, len(pnp) // self.page_size + 1)
                ]
                if self.alloc.share
                else []
            )
            if any(
                k in pending and not self.alloc.has_prefix(k) for k in keys
            ):
                self.queue.insert(0, req)  # share with this round's donor
                break  # once it registers, next round
            plan = (
                self.alloc.admit_windowed(s)
                if self._windowed
                else self.alloc.admit(s, pnp)
            )
            if plan is None:  # pool dry: wait for retires to free pages
                self.queue.insert(0, req)
                break
            pending.update(keys)
            placed.append((req, s, plan))
        return placed

    def _admit(self):
        while self.queue and any(s is None for s in self.slots):
            free = [i for i, s in enumerate(self.slots) if s is None]
            limit = 1 if self.admit_mode == "per-slot" else len(free)
            placed = self._plan_batch(free, limit)
            if not placed:
                if (
                    self.alloc is not None
                    and self.queue
                    and all(r is None for r in self.slots)
                ):
                    req = self.queue[0]
                    raise CacheOverflowError(
                        f"request {req.uid}: page pool exhausted with no "
                        "active slots to retire; raise num_pages"
                    )
                return
            B = self.num_slots
            tails = [
                np.asarray(r.prompt, np.int32)[(p.start if p else 0):]
                for r, _, p in placed
            ]
            S = self.prefill_bucket or _bucket(
                max(len(t) for t in tails), self._T
            )
            tokens = np.zeros((B, S), np.int32)
            lens = np.ones((B,), np.int32)
            admit = np.zeros((B,), bool)
            uids = np.zeros((B,), np.int32)
            max_news = np.ones((B,), np.int32)
            starts = np.zeros((B,), np.int32)
            copy_src = np.full((B,), -1, np.int32)
            copy_dst = np.full((B,), -1, np.int32)
            for (req, s, plan), tail in zip(placed, tails):
                tokens[s, : len(tail)] = tail
                lens[s], admit[s] = len(tail), True
                uids[s], max_news[s] = req.uid, req.max_new
                if plan is not None:
                    starts[s] = plan.start
                    copy_src[s], copy_dst[s] = plan.copy_src, plan.copy_dst
            if self.alloc is not None:
                self.cache["page_table"] = jnp.asarray(self.alloc.table)
                self.cache, packed = self._prefill(
                    self.params, self.cache, tokens, lens, admit, uids,
                    max_news, self._base_key, starts, copy_src, copy_dst,
                )
                for (req, s, plan), tail in zip(placed, tails):
                    # the prefill writing these pages has been issued: safe
                    # to register them for future admissions to map
                    self.alloc.register(s, np.asarray(req.prompt, np.int32))
                    self._slot_len[s] = int(plan.start) + len(tail)
            else:
                self.cache, packed = self._prefill(
                    self.params, self.cache, tokens, lens, admit, uids,
                    max_news, self._base_key,
                )
                for (req, s, _), tail in zip(placed, tails):
                    self._slot_len[s] = len(tail)
            self.prefill_tokens += int(sum(len(t) for t in tails))
            arr = self._check(packed)
            for (req, s, _), _tail in zip(placed, tails):
                req.generated.append(int(arr[s, 0]))
                if arr[s, 1]:  # EOS at prefill or max_new == 1: free the
                    req.done = True  # slot now; keep admitting into it
                    if self.alloc is not None:
                        self.alloc.retire(s)
                else:
                    self.slots[s] = req

    def step(self) -> bool:
        """One decode step over all active slots; returns True if any active."""
        self._admit()
        if all(r is None for r in self.slots):
            return False
        if self.alloc is not None:
            dirty = False
            for s, req in enumerate(self.slots):
                if req is not None:
                    try:
                        # the decode below writes this slot's KV at its
                        # current length: map that page before tracing
                        dirty |= self.alloc.ensure_page(s, self._slot_len[s])
                    except PagePoolExhausted as e:
                        raise CacheOverflowError(str(e)) from None
            if dirty:
                self.cache["page_table"] = jnp.asarray(self.alloc.table)
        self.cache, packed = self._decode(self.params, self.cache)
        arr = self._check(packed)
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            self._slot_len[s] += 1
            req.generated.append(int(arr[s, 0]))
            if arr[s, 1]:
                req.done = True
                self.slots[s] = None
                if self.alloc is not None:
                    self.alloc.retire(s)
        return True

    def run(self) -> list[Request]:
        all_reqs = list(self.queue)
        while self.step():
            pass
        return all_reqs
