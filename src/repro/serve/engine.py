"""Deprecated module path — import from :mod:`repro.serve` instead.

Every attribute still resolves (forwarded to ``repro.serve._engine``) but
emits a ``DeprecationWarning``; this shim is removed next release.
"""
from __future__ import annotations

import warnings

from repro.serve import _engine


def __getattr__(name: str):
    if name.startswith("__"):  # import machinery probes; never warn
        raise AttributeError(name)
    try:
        value = getattr(_engine, name)
    except AttributeError:
        raise AttributeError(
            f"module 'repro.serve.engine' has no attribute {name!r}"
        ) from None
    warnings.warn(
        "repro.serve.engine is deprecated; import from repro.serve instead "
        "(this shim is removed next release)",
        DeprecationWarning,
        stacklevel=2,
    )
    return value
