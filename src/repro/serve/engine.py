"""Serving engine: a device-resident batched scheduler over slot caches.

The TableNet integration is first-class: pass ``lut_params`` (from
``core.convert.convert_params``, ideally per-layer-planned via
``core.planner.plan_model``) and every converted projection executes via
the paper's LUT path — ``ExecCfg(use_pallas=True)`` routes through the
Pallas kernel on real devices, the jnp oracle otherwise, and
``ExecCfg(lut_grouped=True)`` additionally fuses same-shape projections
(QKV, gate/up) into one grouped dispatch per decode step.  The scheduler
is agnostic to all of it: both steps inherit the choice from the ``Ctx``
they are built with, so the grouped pre-stacked fast path rides through
unchanged.

Scheduler architecture (``BatchingEngine``):

* **Device-resident slot state.**  The cache carries, besides the KV ring,
  per-slot ``slot_active`` / ``slot_remaining`` / ``slot_key`` /
  ``next_tok`` / ``overflow`` leaves.  Both the prefill and the decode
  step are jitted functions ``(params, cache, ...) -> (cache, packed)``
  whose cache argument is **donated** — steady-state decode does zero
  full-cache allocations (XLA aliases every cache buffer in place) and no
  host-side cache surgery ever happens (the old ``_splice_cache``
  full-cache copies are gone).
* **Fused on-device sampling.**  ``SampleCfg`` (greedy / temperature /
  top-k) executes inside the jitted steps.  Non-greedy draws use
  ``fold_in(slot_key, index)`` — ``slot_key`` is derived from the request
  uid at admission and ``index`` is the slot's write offset — so a sampled
  stream is a pure function of (engine seed, uid, position) and identical
  under batched-admit and per-slot-admit schedules.
* **Batched multi-slot prefill.**  Admission right-pads up to
  ``num_slots`` queued prompts into one (num_slots, S_bucket) batch and
  runs ONE prefill that writes each prompt directly into its slot via the
  one-hot slot machinery (``token_mask`` masks pad positions and
  mid-decode slots).  ``admit="per-slot"`` admits one request per prefill
  call instead — same compiled step, more calls (the measured baseline in
  ``benchmarks/serving.py``).
* **One small readback per step.**  Each step returns a packed (B, 3)
  int32 array ``[token, done, overflow]``; ``step()`` reads it back once
  (steady-state decode: exactly one host readback; an admission round
  adds one for its prefill).  Blocking per-slot ``int(...)`` scalar syncs
  are gone.

Overflow policy: requests that cannot fit (``prompt + max_new - 1 >
max_len``) raise :class:`CacheOverflowError` at ``submit()``; the packed
``overflow`` column (accumulated by the cache layer whenever a write slot
would fall past ``max_len``) is checked on every readback as a backstop,
so overflowing tokens can never be silently dropped.

``decode_step`` is what the decode_32k / long_500k dry-run cells lower: one
new token against a seq_len-deep cache, caches seq-sharded over the model
axis (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, SampleCfg, sample_tokens
from repro.models.model import model_forward
from repro.models.params import abstract_params, init_params
from repro.serve.cache import CacheOverflowError, cache_specs

__all__ = [
    "BatchingEngine",
    "CacheOverflowError",
    "Request",
    "SampleCfg",
    "abstract_cache",
    "generate",
    "make_cache",
    "make_decode_step",
    "make_prefill_step",
]

# families whose caches support slot-targeted masked prefill writes
_ENGINE_FAMILIES = ("dense", "moe", "vlm")


def make_cache(
    cfg: ModelConfig, batch: int, max_len: int, ctx: Ctx, dtype=jnp.bfloat16
):
    specs = cache_specs(cfg, batch, max_len)
    return init_params(specs, jax.random.PRNGKey(0), default_dtype=dtype)


def abstract_cache(
    cfg: ModelConfig, batch: int, max_len: int, ctx: Ctx, dtype=jnp.bfloat16
):
    specs = cache_specs(cfg, batch, max_len)
    return abstract_params(
        specs,
        default_dtype=dtype,
        sharding_fn=(
            ctx.shard.param_sharding if ctx.shard.mesh is not None else None
        ),
    )


def _serve_ctx(ctx: Ctx) -> Ctx:
    return dataclasses.replace(ctx, ex=dataclasses.replace(ctx.ex, remat="none"))


def _slot_keys(cache: dict) -> jax.Array:
    """Per-slot sampling keys at the current write offsets (B, 2) uint32."""
    return jax.vmap(jax.random.fold_in)(cache["slot_key"], cache["index"])


def make_prefill_step(ctx: Ctx) -> Callable:
    """(params, inputs, cache) -> (last-token logits, filled cache)."""
    sctx = _serve_ctx(ctx)

    def prefill(params, inputs, cache):
        logits, cache, _ = model_forward(params, inputs, sctx, cache=cache)
        return logits[:, -1:], cache

    return prefill


def make_decode_step(ctx: Ctx, sample: SampleCfg | None = None) -> Callable:
    """(params, cache, tokens (B,1)) -> (next tokens (B,1), logits, cache).

    With a non-greedy ``sample``, the cache must carry a ``slot_key`` leaf
    ((B, 2) uint32 per-row PRNG keys); sampling runs fused on device.
    """
    scfg = sample or SampleCfg()
    sctx = _serve_ctx(ctx)

    def decode(params, cache, tokens):
        logits, cache, _ = model_forward(
            params, {"tokens": tokens}, sctx, cache=cache
        )
        keys = _slot_keys(cache) if scfg.mode != "greedy" else None
        nxt = sample_tokens(logits[:, -1], scfg, keys)[:, None]
        return nxt, logits, cache

    return decode


def generate(
    params,
    ctx: Ctx,
    prompts: jax.Array,
    max_new: int,
    max_len: int | None = None,
    eos_id: Optional[int] = None,
    enc_embeds: jax.Array | None = None,
    embeds: jax.Array | None = None,
    sample: SampleCfg | None = None,
    key: jax.Array | None = None,
) -> jax.Array:
    """Reference generation loop used by tests/examples.

    Semantics are aligned with :class:`BatchingEngine`: each row stops at
    its first ``eos_id`` token (the EOS itself is emitted); since the
    return value is rectangular (B, max_new), positions past a row's EOS
    are padded with ``eos_id``.  Non-greedy ``sample`` draws with
    ``fold_in(fold_in(key, row), position)`` per row.  Raises
    :class:`CacheOverflowError` up front when ``prompt + max_new - 1``
    writes cannot fit in ``max_len`` (a non-windowed cache would silently
    drop the overflowing tokens otherwise — the pre-PR4 bug).
    """
    B, S = prompts.shape
    scfg = sample or SampleCfg()
    pre = S + (embeds.shape[1] if embeds is not None else 0)
    T = max_len or (pre + max_new)
    if ctx.cfg.sliding_window is None and pre + max_new - 1 > T:
        raise CacheOverflowError(
            f"prompt ({pre} tokens) + max_new ({max_new}) needs "
            f"{pre + max_new - 1} cache slots but max_len is {T}; raise "
            "max_len — overflowing one-hot writes would drop tokens"
        )
    cache = make_cache(ctx.cfg, B, T, ctx)
    if scfg.mode != "greedy":
        base = key if key is not None else jax.random.PRNGKey(0)
        cache["slot_key"] = jax.vmap(
            lambda r: jax.random.fold_in(base, r)
        )(jnp.arange(B, dtype=jnp.int32))
    prefill = jax.jit(make_prefill_step(ctx), donate_argnums=(2,))
    decode = jax.jit(make_decode_step(ctx, scfg), donate_argnums=(1,))
    inputs = {"tokens": prompts}
    if enc_embeds is not None:
        inputs["enc_embeds"] = enc_embeds
    if embeds is not None:
        inputs["embeds"] = embeds
    logits, cache = prefill(params, inputs, cache)
    keys = _slot_keys(cache) if scfg.mode != "greedy" else None
    tok = sample_tokens(logits[:, -1], scfg, keys)[:, None]
    out = [tok]
    done = np.zeros((B,), bool)
    for _ in range(max_new - 1):
        if eos_id is not None:
            done = done | (np.asarray(tok[:, 0]) == eos_id)
            if done.all():
                break
        tok, _, cache = decode(params, cache, tok)
        if eos_id is not None:
            tok = jnp.where(jnp.asarray(done)[:, None], eos_id, tok)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    if toks.shape[1] < max_new:  # every row hit EOS early: pad rectangle
        pad = jnp.full((B, max_new - toks.shape[1]), eos_id, jnp.int32)
        toks = jnp.concatenate([toks, pad], axis=1)
    return toks


# ---------------------------------------------------------------------------
# Device-resident batched scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any  # (S,) int32
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@functools.lru_cache(maxsize=32)
def _engine_steps(ctx: Ctx, scfg: SampleCfg, eos_id: Optional[int]):
    """Compiled engine steps, shared across engine instances (lru-cached so
    repeated engine construction — benchmarks, tests — never recompiles).

    prefill: (params, cache, tokens, lens, admit, uids, max_news, base_key)
             -> (cache, packed)
    decode:  (params, cache) -> (cache, packed)
    with packed (B, 3) int32 = [sampled token, done, overflow] — the single
    small array the host reads back per step.  Both donate their cache.
    """
    # force logits="all": the batched prefill gathers each slot's logits at
    # its own last REAL position (lens - 1); under logits="last" the model
    # would return only the right-padded final position's head — pad logits
    sctx = dataclasses.replace(
        ctx, ex=dataclasses.replace(ctx.ex, remat="none", logits="all")
    )

    def _sample(last, cache):
        keys = _slot_keys(cache) if scfg.mode != "greedy" else None
        return sample_tokens(last, scfg, keys)

    def _packed(tok, done, cache):
        return jnp.stack(
            [tok, done.astype(jnp.int32), cache["overflow"].astype(jnp.int32)],
            axis=1,
        )

    def prefill(params, cache, tokens, lens, admit, uids, max_news, base_key):
        B, S = tokens.shape
        fresh_keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
        adm1 = admit[:, None]
        cache = dict(
            cache,
            index=jnp.where(admit, 0, cache["index"]),
            pos=jnp.where(adm1, 0, cache["pos"]),
            valid=cache["valid"] & ~adm1,
            overflow=cache["overflow"] & ~admit,
            slot_key=jnp.where(adm1, fresh_keys, cache["slot_key"]),
            slot_remaining=jnp.where(admit, max_news - 1, cache["slot_remaining"]),
        )
        mask = (jnp.arange(S, dtype=jnp.int32)[None, :] < lens[:, None]) & adm1
        logits, cache, _ = model_forward(
            params, {"tokens": tokens, "token_mask": mask}, sctx, cache=cache
        )
        last = jnp.take_along_axis(
            logits, jnp.maximum(lens - 1, 0)[:, None, None], axis=1
        )[:, 0]
        tok = _sample(last, cache)
        eos_hit = (tok == eos_id) if eos_id is not None else jnp.zeros_like(admit)
        done = admit & (eos_hit | (cache["slot_remaining"] <= 0))
        cache = dict(
            cache,
            slot_active=(cache["slot_active"] | admit) & ~done,
            next_tok=jnp.where(adm1, tok[:, None], cache["next_tok"]),
        )
        return cache, _packed(tok, done, cache)

    def decode(params, cache):
        active = cache["slot_active"]
        logits, cache, _ = model_forward(
            params,
            {"tokens": cache["next_tok"], "token_mask": active[:, None]},
            sctx,
            cache=cache,
        )
        tok = _sample(logits[:, -1], cache)
        remaining = cache["slot_remaining"] - active.astype(jnp.int32)
        eos_hit = (tok == eos_id) if eos_id is not None else jnp.zeros_like(active)
        done = active & (eos_hit | (remaining <= 0))
        cache = dict(
            cache,
            slot_remaining=remaining,
            slot_active=active & ~done,
            next_tok=jnp.where(active[:, None], tok[:, None], cache["next_tok"]),
        )
        return cache, _packed(tok, done, cache)

    return (
        jax.jit(prefill, donate_argnums=(1,)),
        jax.jit(decode, donate_argnums=(1,)),
    )


def _bucket(n: int, cap: int) -> int:
    """Right-pad prompts to a power-of-two bucket (bounds recompilation)."""
    b = 4
    while b < n:
        b *= 2
    return min(b, cap)


class BatchingEngine:
    """Fixed-slot continuous batching, fully device-resident: finished
    sequences are swapped out for queued requests between decode steps via
    batched masked prefill (see the module docstring for the scheduler
    architecture, sampling determinism, readback and overflow contracts).
    """

    def __init__(
        self,
        params,
        ctx: Ctx,
        num_slots: int,
        max_len: int,
        eos_id: Optional[int] = None,
        sample: SampleCfg | None = None,
        seed: int = 0,
        admit: str = "batched",
        prefill_bucket: int | None = None,
    ):
        if ctx.cfg.family not in _ENGINE_FAMILIES:
            raise NotImplementedError(
                f"BatchingEngine needs slot-targeted cache writes; family "
                f"{ctx.cfg.family!r} has recurrent/cross caches without them"
            )
        if admit not in ("batched", "per-slot"):
            raise ValueError(f"admit must be 'batched' or 'per-slot': {admit!r}")
        self.params, self.ctx = params, ctx
        self.num_slots, self.max_len = num_slots, max_len
        self.eos_id = eos_id
        self.sample = sample or SampleCfg()
        self.admit_mode = admit
        self.queue: list[Request] = []
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.cache = make_cache(ctx.cfg, num_slots, max_len, ctx)
        self._T = self.cache["pos"].shape[1]  # min(window, max_len) for SWA
        self.prefill_bucket = prefill_bucket
        if prefill_bucket is not None and prefill_bucket > self._T:
            raise ValueError(
                f"prefill_bucket {prefill_bucket} exceeds cache capacity {self._T}"
            )
        self.cache.update(
            overflow=jnp.zeros((num_slots,), bool),
            slot_active=jnp.zeros((num_slots,), bool),
            slot_remaining=jnp.zeros((num_slots,), jnp.int32),
            slot_key=jnp.zeros((num_slots, 2), jnp.uint32),
            next_tok=jnp.zeros((num_slots, 1), jnp.int32),
        )
        self._base_key = jax.random.PRNGKey(seed)
        self._prefill, self._decode = _engine_steps(ctx, self.sample, eos_id)
        self.readbacks = 0  # host syncs: 1/decode step + 1/admission prefill

    def submit(self, req: Request):
        plen = int(req.prompt.shape[0])
        if plen < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        cap = self.prefill_bucket or self._T
        if plen > cap:
            raise ValueError(
                f"request {req.uid}: prompt ({plen}) exceeds the prefill "
                f"capacity ({cap} tokens)"
            )
        if (
            self.ctx.cfg.sliding_window is None
            and plen + req.max_new - 1 > self.max_len
        ):
            raise CacheOverflowError(
                f"request {req.uid}: prompt ({plen}) + max_new ({req.max_new}) "
                f"needs {plen + req.max_new - 1} cache slots but max_len is "
                f"{self.max_len}; overflowing writes would drop tokens"
            )
        self.queue.append(req)

    def _check(self, packed) -> np.ndarray:
        """The ONE host readback per step; backstop overflow check."""
        arr = np.asarray(packed)
        self.readbacks += 1
        if arr[:, 2].any():
            raise CacheOverflowError(
                f"cache overflow flagged for slots {arr[:, 2].nonzero()[0].tolist()}"
            )
        return arr

    def _admit(self):
        while self.queue and any(s is None for s in self.slots):
            free = [i for i, s in enumerate(self.slots) if s is None]
            limit = 1 if self.admit_mode == "per-slot" else len(free)
            batch: list[Request] = []
            while self.queue and len(batch) < limit:
                req = self.queue.pop(0)
                if req.max_new <= 0:
                    req.done = True  # nothing requested; don't pay a prefill
                    continue
                batch.append(req)
            if not batch:
                return
            B = self.num_slots
            S = self.prefill_bucket or _bucket(
                max(int(r.prompt.shape[0]) for r in batch), self._T
            )
            tokens = np.zeros((B, S), np.int32)
            lens = np.ones((B,), np.int32)
            admit = np.zeros((B,), bool)
            uids = np.zeros((B,), np.int32)
            max_news = np.ones((B,), np.int32)
            placed = list(zip(batch, free))
            for req, s in placed:
                plen = int(req.prompt.shape[0])
                tokens[s, :plen] = np.asarray(req.prompt)
                lens[s], admit[s] = plen, True
                uids[s], max_news[s] = req.uid, req.max_new
            self.cache, packed = self._prefill(
                self.params, self.cache, tokens, lens, admit, uids,
                max_news, self._base_key,
            )
            arr = self._check(packed)
            for req, s in placed:
                req.generated.append(int(arr[s, 0]))
                if arr[s, 1]:  # EOS at prefill or max_new == 1: free the
                    req.done = True  # slot now; keep admitting into it
                else:
                    self.slots[s] = req

    def step(self) -> bool:
        """One decode step over all active slots; returns True if any active."""
        self._admit()
        if all(r is None for r in self.slots):
            return False
        self.cache, packed = self._decode(self.params, self.cache)
        arr = self._check(packed)
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(arr[s, 0]))
            if arr[s, 1]:
                req.done = True
                self.slots[s] = None
        return True

    def run(self) -> list[Request]:
        all_reqs = list(self.queue)
        while self.step():
            pass
        return all_reqs
