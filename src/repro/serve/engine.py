"""Serving engine: prefill/decode steps + a slot-based continuous batcher.

The TableNet integration is first-class: pass ``lut_params`` (from
``core.convert.convert_params``, ideally per-layer-planned via
``core.planner.plan_model``) and every converted projection executes via
the paper's LUT path — ``ExecCfg(use_pallas=True)`` routes through the
Pallas kernel on real devices, the jnp oracle otherwise, and
``ExecCfg(lut_grouped=True)`` additionally fuses same-shape projections
(QKV, gate/up) into one grouped dispatch per decode step
(``kernels.lut_affine.lut_affine_grouped``) instead of one per projection.
Both ``make_decode_step`` and ``BatchingEngine`` inherit the choice from
the ``Ctx`` they are built with.

``decode_step`` is what the decode_32k / long_500k dry-run cells lower: one
new token against a seq_len-deep cache, caches seq-sharded over the model
axis (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx
from repro.models.model import model_forward
from repro.models.params import abstract_params, init_params
from repro.serve.cache import cache_specs


def make_cache(
    cfg: ModelConfig, batch: int, max_len: int, ctx: Ctx, dtype=jnp.bfloat16
):
    specs = cache_specs(cfg, batch, max_len)
    return init_params(specs, jax.random.PRNGKey(0), default_dtype=dtype)


def abstract_cache(
    cfg: ModelConfig, batch: int, max_len: int, ctx: Ctx, dtype=jnp.bfloat16
):
    specs = cache_specs(cfg, batch, max_len)
    return abstract_params(
        specs,
        default_dtype=dtype,
        sharding_fn=(
            ctx.shard.param_sharding if ctx.shard.mesh is not None else None
        ),
    )


def make_prefill_step(ctx: Ctx) -> Callable:
    """(params, inputs, cache) -> (last-token logits, filled cache)."""
    serve_ctx = dataclasses.replace(ctx, ex=dataclasses.replace(ctx.ex, remat="none"))

    def prefill(params, inputs, cache):
        logits, cache, _ = model_forward(params, inputs, serve_ctx, cache=cache)
        return logits[:, -1:], cache

    return prefill


def make_decode_step(ctx: Ctx, sample: str = "greedy") -> Callable:
    """(params, cache, tokens (B,1)) -> (next tokens (B,1), logits, cache)."""
    serve_ctx = dataclasses.replace(ctx, ex=dataclasses.replace(ctx.ex, remat="none"))

    def decode(params, cache, tokens):
        logits, cache, _ = model_forward(
            params, {"tokens": tokens}, serve_ctx, cache=cache
        )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache

    return decode


def generate(
    params,
    ctx: Ctx,
    prompts: jax.Array,
    max_new: int,
    max_len: int | None = None,
    enc_embeds: jax.Array | None = None,
    embeds: jax.Array | None = None,
) -> jax.Array:
    """Greedy generation (reference implementation used by tests/examples)."""
    B, S = prompts.shape
    T = max_len or (S + max_new)
    cache = make_cache(ctx.cfg, B, T, ctx)
    prefill = jax.jit(make_prefill_step(ctx))
    decode = jax.jit(make_decode_step(ctx))
    inputs = {"tokens": prompts}
    if enc_embeds is not None:
        inputs["enc_embeds"] = enc_embeds
    if embeds is not None:
        inputs["embeds"] = embeds
    logits, cache = prefill(params, inputs, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]
    for _ in range(max_new - 1):
        tok, _, cache = decode(params, cache, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Slot-based continuous batcher
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any  # (S,) int32
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchingEngine:
    """Fixed-slot continuous batching: finished sequences are swapped out for
    queued requests between decode steps (per-slot prefill).  Single-host
    reference implementation of the serving layer's scheduling semantics."""

    def __init__(
        self,
        params,
        ctx: Ctx,
        num_slots: int,
        max_len: int,
        eos_id: Optional[int] = None,
    ):
        self.params, self.ctx = params, ctx
        self.num_slots, self.max_len = num_slots, max_len
        self.eos_id = eos_id
        self.queue: list[Request] = []
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.cache = make_cache(ctx.cfg, num_slots, max_len, ctx)
        self._prefill1 = jax.jit(make_prefill_step(ctx))
        self._decode = jax.jit(make_decode_step(ctx))
        self._next_tok = jnp.zeros((num_slots, 1), jnp.int32)
        self._remaining = [0] * num_slots

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.num_slots):
            # a request that finishes at prefill (max_new=1 or EOS in its
            # first token) frees the slot immediately, so keep admitting
            # into the same slot until one survives into decode
            while self.slots[s] is None and self.queue:
                req = self.queue.pop(0)
                if req.max_new <= 0:
                    req.done = True  # nothing requested; don't pay a prefill
                    continue
                # per-slot prefill on a batch-1 cache, then splice into slot s
                sub = make_cache(self.ctx.cfg, 1, self.max_len, self.ctx)
                logits, sub = self._prefill1(
                    self.params, {"tokens": req.prompt[None, :]}, sub
                )
                tok = int(jnp.argmax(logits[0, -1]))
                req.generated.append(tok)
                hit_eos = self.eos_id is not None and tok == self.eos_id
                if req.max_new <= 1 or hit_eos:
                    req.done = True  # prefill already emitted the only token
                    continue
                self.slots[s] = req
                self.cache = _splice_cache(self.cache, sub, s)
                self._next_tok = self._next_tok.at[s, 0].set(tok)
                self._remaining[s] = req.max_new - 1

    def step(self) -> bool:
        """One decode step over all active slots; returns True if any active."""
        self._admit()
        if all(r is None for r in self.slots):
            return False
        nxt, _, self.cache = self._decode(self.params, self.cache, self._next_tok)
        self._next_tok = nxt
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[s, 0])
            req.generated.append(tok)
            self._remaining[s] -= 1
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if self._remaining[s] <= 0 or hit_eos:
                req.done = True
                self.slots[s] = None
        return True

    def run(self) -> list[Request]:
        all_reqs = list(self.queue)
        while self.step():
            pass
        return all_reqs


def _splice_cache(cache: dict, sub: dict, slot: int) -> dict:
    """Write a batch-1 cache into batch slot ``slot``.  Leaves under
    "layers"/"shared_attn"/"cross" are (L, B, ...) — batch at axis 1;
    metadata leaves (pos/valid/index) are (B, ...) — batch at axis 0."""
    out = {}
    for key, val in cache.items():
        axis = 1 if key in ("layers", "shared_attn", "cross") else 0
        out[key] = jax.tree.map(
            lambda d, s, a=axis: d.at[
                tuple(
                    slice(slot, slot + 1) if i == a else slice(None)
                    for i in range(d.ndim)
                )
            ].set(s),
            val,
            sub[key],
        )
    return out
