"""Decode caches for every architecture family.

Dense-rectangle layouts (leading ``layers`` axis — stacks scan with the
blocks):
  GQA  : k/v      (L, B, T, n_kv, head_dim)     T = max_len or SWA window
  MLA  : c_kv     (L, B, T, kv_lora), k_rope (L, B, T, rope_dim)
  SSD  : conv     (L, B, K-1, conv_dim), state (L, B, H, P, N)
  RWKV : shift_a/shift_c (L, B, d), wkv (L, B, H, hd, hd)
plus shared metadata: pos (B, T) absolute position per slot, valid (B, T),
index () — next write offset.

Paged layouts (``cache_specs(..., page_size=)``): attention K/V storage is
broken into fixed-size pages shared by all slots —
  GQA  : k/v      (L, num_pages, page_size, n_kv, head_dim)
  MLA  : c_kv     (L, num_pages, page_size, kv_lora), k_rope likewise
with a ``page_table`` (B, max_pages) int32 leaf mapping each slot's logical
page group to a physical page (-1 = unmapped).  ``pos``/``valid``/``index``
keep their dense (B, T) shapes — T = max_pages * page_size — so the
metadata contract is unchanged; only the K/V storage is indirected.  Reads
gather a (B, T, ...) logical view through the table with a one-hot page
gather; writes scatter through (page, offset) one-hot pairs.  A write whose
logical slot maps to an unmapped page is *dropped* (all-zero one-hot row)
and flags ``overflow`` — allocation is the serving layer's job
(``repro.serve._paging.PageAllocator``), the in-graph side never allocates.

The cached-sequence dim (T, or the page axis when paged) carries the
``seq_kv`` logical axis => sharded over the *model* mesh axis
(flash-decoding style).  This is the one layout that shards evenly for
every assigned arch (kv head counts 8/10/16/32/40 do not all divide 16; T
always does).  Softmax and the probs@V contraction over the sharded T
insert only tiny (B*H-sized) all-reduces.

Writes use one-hot contractions, never dynamic-update-slice on the sharded
dim (the T5X trick), so updates partition cleanly under GSPMD — the paged
write/gather pairs follow the same discipline.

Overflow policy (non-windowed caches): a write slot ``>= T`` — or, when
paged, one that lands in an unmapped page — has an all-zero one-hot row, so
the token would be *silently dropped* — never clamped or wrapped.  Instead
of dropping, every advance records a per-slot ``overflow`` flag (when the
cache carries one) that the serving layer reads back and RAISES on
(:class:`CacheOverflowError`); host-side entry points (``generate``,
``BatchingEngine.submit``) additionally reject requests that cannot fit
before anything is traced.  Setting ``REPRO_CACHE_CHECKS=1`` arms an
in-graph debug assert that raises from inside the computation.

Masked writes: ``advance_meta(..., token_mask=)`` supports right-padded
multi-slot prefill — masked-out tokens write nothing and do not advance the
per-slot ``index``, so a single batched prefill can admit several requests
into their slots while leaving mid-decode slots untouched.
"""
from __future__ import annotations

import os
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec


class CacheOverflowError(ValueError):
    """A non-windowed cache write would land past the sequence capacity T
    (or, for paged caches, in a page no allocator ever mapped).

    One-hot rows for out-of-range slots are all-zero, so without this guard
    the overflowing tokens would be silently dropped (the pre-PR4 bug)."""


class CacheWrite(NamedTuple):
    """Typed result of :func:`advance_meta`: everything a per-layer write
    needs, replacing the old parallel-dict-keys convention.

    Always populated for attention caches:
      slots     (B, S) int32 — explicit write slot per token (post ring
                slicing; layers never reconstruct slots from index math)
      mask      (B, S) bool or None — write mask (None = write everything);
                for paged caches, tokens whose page is unmapped are masked
                out here too, so metadata never claims unwritten K/V
      positions (B, S) int32 — absolute positions written (post slicing)
      overflow  (B,) bool or None — accumulated per-slot overflow flags
      pos/valid post-write metadata views; index is the PRE-write per-slot
      offset (gates the fresh-row S == T fast path).

    Paged caches additionally carry:
      page_ids     (B, S) int32 — physical page per token (-1 = dropped)
      page_offsets (B, S) int32 — offset within the page
      page_table   (B, max_pages) int32 — the slot→page map for gathers
    """

    slots: Optional[jax.Array] = None
    mask: Optional[jax.Array] = None
    positions: Optional[jax.Array] = None
    overflow: Optional[jax.Array] = None
    pos: Optional[jax.Array] = None
    valid: Optional[jax.Array] = None
    index: Optional[jax.Array] = None
    page_ids: Optional[jax.Array] = None
    page_offsets: Optional[jax.Array] = None
    page_table: Optional[jax.Array] = None


# ---------------------------------------------------------------------------
# Cache spec construction (PSpec trees -> works for init AND dry-run)
# ---------------------------------------------------------------------------


def cache_specs(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=None,
    page_size: int | None = None,
    num_pages: int | None = None,
) -> dict:
    """PSpec tree for a fresh decode cache.

    With ``page_size`` set, attention K/V storage is paged: physical leaves
    become (L, num_pages, page_size, ...) plus a (batch, max_pages) int32
    ``page_table``.  ``num_pages`` defaults to ``batch * max_pages`` (every
    slot can hold a full rectangle — prefix sharing only shrinks from
    there).  The ring/window capacity must divide evenly into pages so the
    paged modulus matches the dense one exactly.
    """
    T = max_len if cfg.sliding_window is None else min(cfg.sliding_window, max_len)
    L = cfg.num_layers
    if page_size is not None:
        if cfg.family == "ssm":
            raise ValueError("page_size is meaningless for O(1)-state families")
        if T % page_size:
            raise ValueError(
                f"cache capacity {T} must be a whole number of pages "
                f"(page_size {page_size}); pad max_len or the window"
            )
        max_pages = T // page_size
        if num_pages is None:
            num_pages = batch * max_pages
    tree: dict[str, Any] = {
        "pos": PSpec((batch, T), ("batch", "seq_kv"), init="zeros", dtype=jnp.int32),
        "valid": PSpec((batch, T), ("batch", "seq_kv"), init="zeros", dtype=jnp.bool_),
        # per-sequence write offset: continuous batching gives slots
        # different lengths
        "index": PSpec((batch,), ("batch",), init="zeros", dtype=jnp.int32),
    }
    if page_size is not None:
        tree["page_table"] = PSpec(
            (batch, max_pages), ("batch", None), init="zeros", dtype=jnp.int32
        )

    def kv(n_layers):
        if page_size is not None:
            shape = (n_layers, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
            axes = ("layers", "seq_kv", None, None, None)
        else:
            shape = (n_layers, batch, T, cfg.num_kv_heads, cfg.head_dim)
            axes = ("layers", "batch", "seq_kv", None, None)
        return {
            "k": PSpec(shape, axes, init="zeros"),
            "v": PSpec(shape, axes, init="zeros"),
        }

    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attention == "mla":
            if page_size is not None:
                lead, axes = (L, num_pages, page_size), ("layers", "seq_kv", None, None)
            else:
                lead, axes = (L, batch, T), ("layers", "batch", "seq_kv", None)
            tree["layers"] = {
                "c_kv": PSpec(lead + (cfg.kv_lora_rank,), axes, init="zeros"),
                "k_rope": PSpec(lead + (cfg.qk_rope_head_dim,), axes, init="zeros"),
            }
        else:
            tree["layers"] = kv(L)
    elif cfg.family == "hybrid":  # zamba2: ssd states + shared-attn kv caches
        n_shared = _num_shared_invocations(cfg)
        tree["layers"] = _ssd_state_specs(cfg, L, batch)
        tree["shared_attn"] = kv(n_shared)
    elif cfg.family == "ssm":  # rwkv6
        H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
        shift_axes = ("layers", "batch", None)
        tree["layers"] = {
            "shift_a": PSpec((L, batch, cfg.d_model), shift_axes, init="zeros"),
            "shift_c": PSpec((L, batch, cfg.d_model), shift_axes, init="zeros"),
            "wkv": PSpec(
                (L, batch, H, hd, hd),
                ("layers", "batch", "heads", None, None),
                init="zeros",
                dtype=jnp.float32,
            ),
        }
        # rwkv needs no pos/valid ring: state is O(1)
        tree.pop("pos"), tree.pop("valid")
    elif cfg.family == "encdec":  # whisper: decoder self-KV + static cross-KV
        tree["layers"] = kv(L)
        # cross-KV is written once at prefill and never grows: a dense
        # rectangle regardless of paging
        tree["cross"] = {
            "k": PSpec(
                (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
            "v": PSpec(
                (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
        }
    else:
        raise ValueError(cfg.family)
    return tree


def _num_shared_invocations(cfg: ModelConfig) -> int:
    if not cfg.shared_attn_every:
        return 0
    return cfg.num_layers // cfg.shared_attn_every


def _ssd_state_specs(cfg: ModelConfig, L: int, batch: int) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": PSpec(
            (L, batch, cfg.conv_kernel - 1, conv_dim),
            ("layers", "batch", None, None),
            init="zeros",
        ),
        "state": PSpec(
            (L, batch, cfg.mamba_heads, cfg.mamba_head_dim, cfg.ssm_state),
            ("layers", "batch", "heads", None, None),
            init="zeros",
            dtype=jnp.float32,
        ),
    }


# ---------------------------------------------------------------------------
# Metadata advance (once per step) + one-hot writes (per layer)
# ---------------------------------------------------------------------------


def _debug_overflow_assert(overflowed: jax.Array) -> None:
    """Env-gated in-graph assert (REPRO_CACHE_CHECKS=1): raise from inside
    the computation when any slot overflowed its cache row."""
    if not os.environ.get("REPRO_CACHE_CHECKS"):
        return

    def _check(o):
        if bool(o.any()):
            raise CacheOverflowError(
                "cache write past max_len detected in-graph "
                f"(overflowed slots: {o.nonzero()[0].tolist()})"
            )

    jax.debug.callback(_check, overflowed)


def advance_meta(
    cache: dict,
    positions: jax.Array,
    window: int | None,
    token_mask: jax.Array | None = None,
) -> tuple[dict, CacheWrite]:
    """Advance pos/valid/index for the S tokens written this step.

    Returns ``(new_cache, write)`` where ``write`` is a :class:`CacheWrite`
    carrying everything the per-layer writes need: post-write
    ``pos``/``valid``, the *pre-write* per-slot ``index``, the explicit
    write ``slots`` (B, S) and the write ``mask`` ((B, S) bool or None) —
    layers never reconstruct slots from index arithmetic.  ``token_mask``
    marks real tokens in a right-padded batch: masked positions write
    nothing and do not advance ``index``.  For paged caches the write also
    carries per-token (page, offset) pairs resolved through the slot's
    ``page_table`` row; tokens whose page is unmapped are dropped from the
    write mask and flag ``overflow``.
    """
    S_consumed = positions.shape[1]
    if "pos" not in cache:  # O(1)-state families (rwkv): index only
        adv = (
            token_mask.sum(1).astype(jnp.int32)
            if token_mask is not None
            else S_consumed
        )
        new = dict(cache, index=cache["index"] + adv)
        return new, CacheWrite(positions=positions, index=cache["index"])
    T = cache["pos"].shape[1]
    paged = "page_table" in cache
    S = S_consumed
    mask = token_mask
    if window is not None and S > T:
        # ring cache: only the last T tokens survive; slicing first keeps
        # slot writes unique (T consecutive positions mod T is a permutation)
        positions = positions[:, -T:]
        mask = mask[:, -T:] if mask is not None else None
        S = T
    meta_mask = mask
    if window is not None:
        slots = positions % T
        overflow = cache.get("overflow")
    else:
        slots = cache["index"][:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        over = slots >= T  # would be an all-zero one-hot row: token dropped
        if mask is not None:
            over = over & mask
        over_rows = over.any(1)
        if not paged:
            _debug_overflow_assert(over_rows)
        overflow = (
            cache["overflow"] | over_rows if "overflow" in cache else None
        )
        if mask is None and S == T and not paged:
            # the per-layer writes take the whole-row fast path here
            # (:func:`_fresh_overwrite`), which cannot express a partially
            # in-range (0 < index < T) write — suppress those rows' pos/
            # valid writes too, so metadata never claims slots whose K/V
            # were not written (the row is flagged overflow above instead)
            meta_mask = jnp.broadcast_to(
                (cache["index"] == 0)[:, None], slots.shape
            )
    page_ids = page_offsets = table = None
    if paged:
        table = cache["page_table"]
        max_pages = table.shape[1]
        page_size = T // max_pages
        grp = jnp.clip(slots // page_size, 0, max_pages - 1)
        page_offsets = slots % page_size
        pid = jnp.take_along_axis(table, grp, axis=1)
        # a token is dropped when its slot is out of range OR its page was
        # never mapped by the allocator — either way the one-hot row is
        # all-zero, so flag it instead of losing the token silently
        dropped = (slots >= T) | (pid < 0)
        if mask is not None:
            dropped = dropped & mask
        drop_rows = dropped.any(1)
        _debug_overflow_assert(drop_rows)
        if overflow is not None:
            overflow = overflow | drop_rows
        page_ids = jnp.where(dropped, -1, pid)
        # dropped tokens write neither K/V (page -1) nor pos/valid
        mask = mask & ~dropped if mask is not None else ~dropped
        meta_mask = mask
    mvalid = (
        meta_mask.astype(jnp.int32)[..., None]
        if meta_mask is not None
        else jnp.ones(slots.shape + (1,), jnp.int32)
    )
    oh = jax.nn.one_hot(slots, T, dtype=jnp.int32) * mvalid  # (B, S, T)
    written = oh.sum(1)  # (B, T)
    pos = cache["pos"] * (1 - written) + jnp.einsum(
        "bst,bs->bt", oh, positions.astype(jnp.int32)
    )
    valid = cache["valid"] | (written > 0)
    adv = (
        token_mask.sum(1).astype(jnp.int32)
        if token_mask is not None
        else S_consumed
    )
    new = dict(cache, pos=pos, valid=valid, index=cache["index"] + adv)
    if overflow is not None:
        new["overflow"] = overflow
    write = CacheWrite(
        slots=slots,
        mask=mask,
        positions=positions,
        overflow=overflow,
        pos=pos,
        valid=valid,
        index=cache["index"],  # pre-write offsets (fast-path gating)
        page_ids=page_ids,
        page_offsets=page_offsets,
        page_table=table,
    )
    return new, write


def _onehot_write(
    buf: jax.Array,
    new: jax.Array,
    slots: jax.Array,
    mask: jax.Array | None = None,
) -> jax.Array:
    """buf: (B, T, ...); new: (B, S, ...); slots: (B, S) -> updated buf.
    ``mask`` (B, S) suppresses writes for padded / inactive positions."""
    T = buf.shape[1]
    oh = jax.nn.one_hot(slots, T, dtype=buf.dtype)  # (B, S, T)
    if mask is not None:
        oh = oh * mask.astype(buf.dtype)[..., None]
    keep = 1 - oh.sum(1)  # (B, T)
    keep = keep.reshape(keep.shape + (1,) * (buf.ndim - 2))
    add = jnp.einsum("bst,bs...->bt...", oh, new)
    return buf * keep + add


def _paged_write(
    buf: jax.Array,
    new: jax.Array,
    page_ids: jax.Array,
    page_offsets: jax.Array,
    mask: jax.Array | None = None,
) -> jax.Array:
    """buf: (num_pages, page_size, ...); new: (B, S, ...) scattered through
    per-token (page, offset) one-hot pairs.  ``page_ids`` -1 rows are
    all-zero one-hots: dropped tokens write nothing (``advance_meta`` has
    already flagged them overflow).  Slots own their mapped pages
    exclusively at write time (COW duplicates shared pages first), so the
    scatter is collision-free by construction."""
    num_pages, page_size = buf.shape[:2]
    ohp = jax.nn.one_hot(page_ids, num_pages, dtype=buf.dtype)  # (B, S, NP)
    oho = jax.nn.one_hot(page_offsets, page_size, dtype=buf.dtype)  # (B, S, PS)
    if mask is not None:
        ohp = ohp * mask.astype(buf.dtype)[..., None]
    keep = 1 - jnp.einsum("bsn,bsp->np", ohp, oho)
    keep = keep.reshape(keep.shape + (1,) * (buf.ndim - 2))
    add = jnp.einsum("bsn,bsp,bs...->np...", ohp, oho, new)
    return buf * keep + add


def paged_view(buf: jax.Array, page_table: jax.Array) -> jax.Array:
    """Gather the (B, T, ...) logical view of paged (num_pages, page_size,
    ...) storage through the slot→page table (one-hot gather over the
    sharded page axis; unmapped -1 entries read as zeros, masked by
    ``valid`` downstream)."""
    num_pages, page_size = buf.shape[:2]
    B, max_pages = page_table.shape
    oh = jax.nn.one_hot(page_table, num_pages, dtype=buf.dtype)  # (B, MP, NP)
    pages = jnp.einsum("bmn,np...->bmp...", oh, buf)  # (B, MP, PS, ...)
    return pages.reshape((B, max_pages * page_size) + buf.shape[2:])


def copy_pages(buf: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Copy whole physical pages ``src[i] -> dst[i]`` in layer-stacked
    (L, num_pages, page_size, ...) storage — the in-graph half of
    copy-on-write: the engine duplicates a shared page into a private one
    before the divergent tail is written over it.  -1 entries are no-ops;
    src/dst must be -1 together."""
    num_pages = buf.shape[1]
    ohs = jax.nn.one_hot(src, num_pages, dtype=buf.dtype)  # (C, NP)
    ohd = jax.nn.one_hot(dst, num_pages, dtype=buf.dtype)
    gathered = jnp.einsum("cn,ln...->lc...", ohs, buf)  # (L, C, PS, ...)
    keep = 1 - ohd.sum(0)  # (NP,)
    keep = keep.reshape((1, num_pages) + (1,) * (buf.ndim - 2))
    add = jnp.einsum("cn,lc...->ln...", ohd, gathered)
    return buf * keep + add


def _fresh_overwrite(buf, new, index):
    """S == T fast path, gated PER ROW on a fresh slot (pre-write index 0):
    fresh rows take the whole-row overwrite; non-fresh rows stay entirely
    unchanged — a (B, S, T) one-hot is never materialized.  A non-fresh
    row's write is rejected as a unit: ``advance_meta`` flags it overflow
    and suppresses its pos/valid updates too (see the ``S == T`` branch
    there), so metadata never claims slots this path did not write.  The
    pre-PR4 bug was overwriting ALL rows from slot 0 regardless of
    ``index``, clobbering mid-decode sequences."""
    sel = (index == 0).reshape((buf.shape[0],) + (1,) * (buf.ndim - 1))
    return jnp.where(sel, new, buf)


def update_kv_cache(cache: dict, k, v, positions, ctx):
    """Write new K/V (B, S, ...) and return full cache views + key metadata.

    ``cache`` is one layer's {"k", "v"} plus the step-level "_meta"
    :class:`CacheWrite` from :func:`advance_meta` (post-write pos/valid,
    pre-write index, explicit write slots + mask, page routing when paged).
    """
    w: CacheWrite = cache["_meta"]
    S_w = w.slots.shape[1]
    if positions.shape[1] > S_w:  # ring: only the last T tokens survive
        k, v = k[:, -S_w:], v[:, -S_w:]
    kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if w.page_ids is not None:
        new_k = _paged_write(cache["k"], kd, w.page_ids, w.page_offsets, w.mask)
        new_v = _paged_write(cache["v"], vd, w.page_ids, w.page_offsets, w.mask)
        new_k = ctx.shard.constrain(new_k, "seq_kv", None, None, None)
        new_v = ctx.shard.constrain(new_v, "seq_kv", None, None, None)
        k_all = paged_view(new_k, w.page_table)
        v_all = paged_view(new_v, w.page_table)
        k_all = ctx.shard.constrain(k_all, "batch", "seq_kv", None, None)
        v_all = ctx.shard.constrain(v_all, "batch", "seq_kv", None, None)
        return {"k": new_k, "v": new_v}, k_all, v_all, w.pos, w.valid
    T = cache["k"].shape[1]
    window = ctx.cfg.sliding_window
    if S_w == T and window is None and w.mask is None:
        new_k = _fresh_overwrite(cache["k"], kd, w.index)
        new_v = _fresh_overwrite(cache["v"], vd, w.index)
    else:
        new_k = _onehot_write(cache["k"], kd, w.slots, w.mask)
        new_v = _onehot_write(cache["v"], vd, w.slots, w.mask)
    new_k = ctx.shard.constrain(new_k, "batch", "seq_kv", None, None)
    new_v = ctx.shard.constrain(new_v, "batch", "seq_kv", None, None)
    return {"k": new_k, "v": new_v}, new_k, new_v, w.pos, w.valid


def update_mla_cache(cache: dict, c_kv, k_rope, positions, ctx):
    w: CacheWrite = cache["_meta"]
    S_w = w.slots.shape[1]
    cd = c_kv.astype(cache["c_kv"].dtype)
    rd = k_rope.astype(cache["k_rope"].dtype)
    if w.page_ids is not None:
        new_c = _paged_write(cache["c_kv"], cd, w.page_ids, w.page_offsets, w.mask)
        new_r = _paged_write(cache["k_rope"], rd, w.page_ids, w.page_offsets, w.mask)
        new_c = ctx.shard.constrain(new_c, "seq_kv", None, None)
        new_r = ctx.shard.constrain(new_r, "seq_kv", None, None)
        c_all = paged_view(new_c, w.page_table)
        r_all = paged_view(new_r, w.page_table)
        c_all = ctx.shard.constrain(c_all, "batch", "seq_kv", None)
        r_all = ctx.shard.constrain(r_all, "batch", "seq_kv", None)
        return {"c_kv": new_c, "k_rope": new_r}, c_all, r_all, w.pos, w.valid
    T = cache["c_kv"].shape[1]
    if S_w == T and w.mask is None:
        new_c = _fresh_overwrite(cache["c_kv"], cd, w.index)
        new_r = _fresh_overwrite(cache["k_rope"], rd, w.index)
    else:
        new_c = _onehot_write(cache["c_kv"], cd, w.slots, w.mask)
        new_r = _onehot_write(cache["k_rope"], rd, w.slots, w.mask)
    new_c = ctx.shard.constrain(new_c, "batch", "seq_kv", None)
    new_r = ctx.shard.constrain(new_r, "batch", "seq_kv", None)
    return {"c_kv": new_c, "k_rope": new_r}, new_c, new_r, w.pos, w.valid
