"""Decode caches for every architecture family.

Layouts (leading ``layers`` axis — stacks scan with the blocks):
  GQA  : k/v      (L, B, T, n_kv, head_dim)     T = max_len or SWA window
  MLA  : c_kv     (L, B, T, kv_lora), k_rope (L, B, T, rope_dim)
  SSD  : conv     (L, B, K-1, conv_dim), state (L, B, H, P, N)
  RWKV : shift_a/shift_c (L, B, d), wkv (L, B, H, hd, hd)
plus shared metadata: pos (B, T) absolute position per slot, valid (B, T),
index () — next write offset.

The cached-sequence dim T carries the ``seq_kv`` logical axis => sharded over
the *model* mesh axis (flash-decoding style).  This is the one layout that
shards evenly for every assigned arch (kv head counts 8/10/16/32/40 do not
all divide 16; T always does).  Softmax and the probs@V contraction over the
sharded T insert only tiny (B*H-sized) all-reduces.

Writes use one-hot contractions, never dynamic-update-slice on the sharded
dim (the T5X trick), so updates partition cleanly under GSPMD.

Overflow policy (non-windowed caches): a write slot ``>= T`` has an all-zero
``jax.nn.one_hot`` row, so the token would be *silently dropped* — never
clamped or wrapped.  Instead of dropping, every advance records a per-slot
``overflow`` flag (when the cache carries one) that the serving layer reads
back and RAISES on (:class:`CacheOverflowError`); host-side entry points
(``generate``, ``BatchingEngine.submit``) additionally reject requests that
cannot fit before anything is traced.  Setting ``REPRO_CACHE_CHECKS=1``
arms an in-graph debug assert that raises from inside the computation.

Masked writes: ``advance_meta(..., token_mask=)`` supports right-padded
multi-slot prefill — masked-out tokens write nothing and do not advance the
per-slot ``index``, so a single batched prefill can admit several requests
into their slots while leaving mid-decode slots untouched.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec


class CacheOverflowError(ValueError):
    """A non-windowed cache write would land past the sequence capacity T.

    One-hot rows for out-of-range slots are all-zero, so without this guard
    the overflowing tokens would be silently dropped (the pre-PR4 bug)."""


# ---------------------------------------------------------------------------
# Cache spec construction (PSpec trees -> works for init AND dry-run)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    """PSpec tree for a fresh decode cache."""
    T = max_len if cfg.sliding_window is None else min(cfg.sliding_window, max_len)
    L = cfg.num_layers
    tree: dict[str, Any] = {
        "pos": PSpec((batch, T), ("batch", "seq_kv"), init="zeros", dtype=jnp.int32),
        "valid": PSpec((batch, T), ("batch", "seq_kv"), init="zeros", dtype=jnp.bool_),
        # per-sequence write offset: continuous batching gives slots
        # different lengths
        "index": PSpec((batch,), ("batch",), init="zeros", dtype=jnp.int32),
    }
    def kv(n_layers):
        return {
            "k": PSpec(
                (n_layers, batch, T, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
            "v": PSpec(
                (n_layers, batch, T, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
        }
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attention == "mla":
            tree["layers"] = {
                "c_kv": PSpec(
                    (L, batch, T, cfg.kv_lora_rank),
                    ("layers", "batch", "seq_kv", None),
                    init="zeros",
                ),
                "k_rope": PSpec(
                    (L, batch, T, cfg.qk_rope_head_dim),
                    ("layers", "batch", "seq_kv", None),
                    init="zeros",
                ),
            }
        else:
            tree["layers"] = kv(L)
    elif cfg.family == "hybrid":  # zamba2: ssd states + shared-attn kv caches
        n_shared = _num_shared_invocations(cfg)
        tree["layers"] = _ssd_state_specs(cfg, L, batch)
        tree["shared_attn"] = kv(n_shared)
    elif cfg.family == "ssm":  # rwkv6
        H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
        shift_axes = ("layers", "batch", None)
        tree["layers"] = {
            "shift_a": PSpec((L, batch, cfg.d_model), shift_axes, init="zeros"),
            "shift_c": PSpec((L, batch, cfg.d_model), shift_axes, init="zeros"),
            "wkv": PSpec(
                (L, batch, H, hd, hd),
                ("layers", "batch", "heads", None, None),
                init="zeros",
                dtype=jnp.float32,
            ),
        }
        # rwkv needs no pos/valid ring: state is O(1)
        tree.pop("pos"), tree.pop("valid")
    elif cfg.family == "encdec":  # whisper: decoder self-KV + static cross-KV
        tree["layers"] = kv(L)
        tree["cross"] = {
            "k": PSpec(
                (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
            "v": PSpec(
                (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
        }
    else:
        raise ValueError(cfg.family)
    return tree


def _num_shared_invocations(cfg: ModelConfig) -> int:
    if not cfg.shared_attn_every:
        return 0
    return cfg.num_layers // cfg.shared_attn_every


def _ssd_state_specs(cfg: ModelConfig, L: int, batch: int) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": PSpec(
            (L, batch, cfg.conv_kernel - 1, conv_dim),
            ("layers", "batch", None, None),
            init="zeros",
        ),
        "state": PSpec(
            (L, batch, cfg.mamba_heads, cfg.mamba_head_dim, cfg.ssm_state),
            ("layers", "batch", "heads", None, None),
            init="zeros",
            dtype=jnp.float32,
        ),
    }


# ---------------------------------------------------------------------------
# Metadata advance (once per step) + one-hot writes (per layer)
# ---------------------------------------------------------------------------


def _debug_overflow_assert(overflowed: jax.Array) -> None:
    """Env-gated in-graph assert (REPRO_CACHE_CHECKS=1): raise from inside
    the computation when any slot overflowed its cache row."""
    if not os.environ.get("REPRO_CACHE_CHECKS"):
        return

    def _check(o):
        if bool(o.any()):
            raise CacheOverflowError(
                "cache write past max_len detected in-graph "
                f"(overflowed slots: {o.nonzero()[0].tolist()})"
            )

    jax.debug.callback(_check, overflowed)


def advance_meta(
    cache: dict,
    positions: jax.Array,
    window: int | None,
    token_mask: jax.Array | None = None,
) -> tuple[dict, dict]:
    """Advance pos/valid/index for the S tokens written this step.

    Returns ``(new_cache, meta)`` where ``meta`` carries everything the
    per-layer writes need: post-write ``pos``/``valid``, the *pre-write*
    per-slot ``index``, the explicit write ``slots`` (B, S) and the write
    ``mask`` ((B, S) bool or None) — layers never reconstruct slots from
    index arithmetic.  ``token_mask`` marks real tokens in a right-padded
    batch: masked positions write nothing and do not advance ``index``.
    """
    S_consumed = positions.shape[1]
    if "pos" not in cache:  # O(1)-state families (rwkv): index only
        adv = (
            token_mask.sum(1).astype(jnp.int32)
            if token_mask is not None
            else S_consumed
        )
        new = dict(cache, index=cache["index"] + adv)
        return new, {"index": cache["index"]}
    T = cache["pos"].shape[1]
    S = S_consumed
    mask = token_mask
    if window is not None and S > T:
        # ring cache: only the last T tokens survive; slicing first keeps
        # slot writes unique (T consecutive positions mod T is a permutation)
        positions = positions[:, -T:]
        mask = mask[:, -T:] if mask is not None else None
        S = T
    meta_mask = mask
    if window is not None:
        slots = positions % T
        overflow = cache.get("overflow")
    else:
        slots = cache["index"][:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        over = slots >= T  # would be an all-zero one-hot row: token dropped
        if mask is not None:
            over = over & mask
        over_rows = over.any(1)
        _debug_overflow_assert(over_rows)
        overflow = (
            cache["overflow"] | over_rows if "overflow" in cache else None
        )
        if mask is None and S == T:
            # the per-layer writes take the whole-row fast path here
            # (:func:`_fresh_overwrite`), which cannot express a partially
            # in-range (0 < index < T) write — suppress those rows' pos/
            # valid writes too, so metadata never claims slots whose K/V
            # were not written (the row is flagged overflow above instead)
            meta_mask = jnp.broadcast_to(
                (cache["index"] == 0)[:, None], slots.shape
            )
    mvalid = (
        meta_mask.astype(jnp.int32)[..., None]
        if meta_mask is not None
        else jnp.ones(slots.shape + (1,), jnp.int32)
    )
    oh = jax.nn.one_hot(slots, T, dtype=jnp.int32) * mvalid  # (B, S, T)
    written = oh.sum(1)  # (B, T)
    pos = cache["pos"] * (1 - written) + jnp.einsum(
        "bst,bs->bt", oh, positions.astype(jnp.int32)
    )
    valid = cache["valid"] | (written > 0)
    adv = (
        token_mask.sum(1).astype(jnp.int32)
        if token_mask is not None
        else S_consumed
    )
    new = dict(cache, pos=pos, valid=valid, index=cache["index"] + adv)
    if overflow is not None:
        new["overflow"] = overflow
    meta = {
        "pos": pos,
        "valid": valid,
        "index": cache["index"],  # pre-write offsets (fast-path gating)
        "slots": slots,
        "mask": mask,
    }
    return new, meta


def _onehot_write(
    buf: jax.Array,
    new: jax.Array,
    slots: jax.Array,
    mask: jax.Array | None = None,
) -> jax.Array:
    """buf: (B, T, ...); new: (B, S, ...); slots: (B, S) -> updated buf.
    ``mask`` (B, S) suppresses writes for padded / inactive positions."""
    T = buf.shape[1]
    oh = jax.nn.one_hot(slots, T, dtype=buf.dtype)  # (B, S, T)
    if mask is not None:
        oh = oh * mask.astype(buf.dtype)[..., None]
    keep = 1 - oh.sum(1)  # (B, T)
    keep = keep.reshape(keep.shape + (1,) * (buf.ndim - 2))
    add = jnp.einsum("bst,bs...->bt...", oh, new)
    return buf * keep + add


def _fresh_overwrite(buf, new, index):
    """S == T fast path, gated PER ROW on a fresh slot (pre-write index 0):
    fresh rows take the whole-row overwrite; non-fresh rows stay entirely
    unchanged — a (B, S, T) one-hot is never materialized.  A non-fresh
    row's write is rejected as a unit: ``advance_meta`` flags it overflow
    and suppresses its pos/valid updates too (see the ``S == T`` branch
    there), so metadata never claims slots this path did not write.  The
    pre-PR4 bug was overwriting ALL rows from slot 0 regardless of
    ``index``, clobbering mid-decode sequences."""
    sel = (index == 0).reshape((buf.shape[0],) + (1,) * (buf.ndim - 1))
    return jnp.where(sel, new, buf)


def update_kv_cache(cache: dict, k, v, positions, ctx):
    """Write new K/V (B, S, ...) and return full cache views + key metadata.

    ``cache`` is one layer's {"k", "v"} plus the step-level "_meta" dict
    from :func:`advance_meta` (post-write pos/valid, pre-write index,
    explicit write slots + mask).
    """
    meta = cache["_meta"]
    T = cache["k"].shape[1]
    window = ctx.cfg.sliding_window
    S = positions.shape[1]
    if window is not None and S > T:  # ring: only the last T tokens survive
        k, v = k[:, -T:], v[:, -T:]
        S = T
    slots, mask = meta["slots"], meta["mask"]
    kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if S == T and window is None and mask is None:
        new_k = _fresh_overwrite(cache["k"], kd, meta["index"])
        new_v = _fresh_overwrite(cache["v"], vd, meta["index"])
    else:
        new_k = _onehot_write(cache["k"], kd, slots, mask)
        new_v = _onehot_write(cache["v"], vd, slots, mask)
    new_k = ctx.shard.constrain(new_k, "batch", "seq_kv", None, None)
    new_v = ctx.shard.constrain(new_v, "batch", "seq_kv", None, None)
    return {"k": new_k, "v": new_v}, new_k, new_v, meta["pos"], meta["valid"]


def update_mla_cache(cache: dict, c_kv, k_rope, positions, ctx):
    meta = cache["_meta"]
    T = cache["c_kv"].shape[1]
    S = positions.shape[1]
    slots, mask = meta["slots"], meta["mask"]
    cd = c_kv.astype(cache["c_kv"].dtype)
    rd = k_rope.astype(cache["k_rope"].dtype)
    if S == T and mask is None:
        new_c = _fresh_overwrite(cache["c_kv"], cd, meta["index"])
        new_r = _fresh_overwrite(cache["k_rope"], rd, meta["index"])
    else:
        new_c = _onehot_write(cache["c_kv"], cd, slots, mask)
        new_r = _onehot_write(cache["k_rope"], rd, slots, mask)
    new_c = ctx.shard.constrain(new_c, "batch", "seq_kv", None)
    new_r = ctx.shard.constrain(new_r, "batch", "seq_kv", None)
    return {"c_kv": new_c, "k_rope": new_r}, new_c, new_r, meta["pos"], meta["valid"]
