"""Decode caches for every architecture family.

Layouts (leading ``layers`` axis — stacks scan with the blocks):
  GQA  : k/v      (L, B, T, n_kv, head_dim)     T = max_len or SWA window
  MLA  : c_kv     (L, B, T, kv_lora), k_rope (L, B, T, rope_dim)
  SSD  : conv     (L, B, K-1, conv_dim), state (L, B, H, P, N)
  RWKV : shift_a/shift_c (L, B, d), wkv (L, B, H, hd, hd)
plus shared metadata: pos (B, T) absolute position per slot, valid (B, T),
index () — next write offset.

The cached-sequence dim T carries the ``seq_kv`` logical axis => sharded over
the *model* mesh axis (flash-decoding style).  This is the one layout that
shards evenly for every assigned arch (kv head counts 8/10/16/32/40 do not
all divide 16; T always does).  Softmax and the probs@V contraction over the
sharded T insert only tiny (B*H-sized) all-reduces.

Writes use one-hot contractions, never dynamic-update-slice on the sharded
dim (the T5X trick), so updates partition cleanly under GSPMD.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec


# ---------------------------------------------------------------------------
# Cache spec construction (PSpec trees -> works for init AND dry-run)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    """PSpec tree for a fresh decode cache."""
    T = max_len if cfg.sliding_window is None else min(cfg.sliding_window, max_len)
    L = cfg.num_layers
    tree: dict[str, Any] = {
        "pos": PSpec((batch, T), ("batch", "seq_kv"), init="zeros", dtype=jnp.int32),
        "valid": PSpec((batch, T), ("batch", "seq_kv"), init="zeros", dtype=jnp.bool_),
        # per-sequence write offset: continuous batching gives slots
        # different lengths
        "index": PSpec((batch,), ("batch",), init="zeros", dtype=jnp.int32),
    }
    def kv(n_layers):
        return {
            "k": PSpec(
                (n_layers, batch, T, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
            "v": PSpec(
                (n_layers, batch, T, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
        }
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attention == "mla":
            tree["layers"] = {
                "c_kv": PSpec(
                    (L, batch, T, cfg.kv_lora_rank),
                    ("layers", "batch", "seq_kv", None),
                    init="zeros",
                ),
                "k_rope": PSpec(
                    (L, batch, T, cfg.qk_rope_head_dim),
                    ("layers", "batch", "seq_kv", None),
                    init="zeros",
                ),
            }
        else:
            tree["layers"] = kv(L)
    elif cfg.family == "hybrid":  # zamba2: ssd states + shared-attn kv caches
        n_shared = _num_shared_invocations(cfg)
        tree["layers"] = _ssd_state_specs(cfg, L, batch)
        tree["shared_attn"] = kv(n_shared)
    elif cfg.family == "ssm":  # rwkv6
        H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
        shift_axes = ("layers", "batch", None)
        tree["layers"] = {
            "shift_a": PSpec((L, batch, cfg.d_model), shift_axes, init="zeros"),
            "shift_c": PSpec((L, batch, cfg.d_model), shift_axes, init="zeros"),
            "wkv": PSpec(
                (L, batch, H, hd, hd),
                ("layers", "batch", "heads", None, None),
                init="zeros",
                dtype=jnp.float32,
            ),
        }
        # rwkv needs no pos/valid ring: state is O(1)
        tree.pop("pos"), tree.pop("valid")
    elif cfg.family == "encdec":  # whisper: decoder self-KV + static cross-KV
        tree["layers"] = kv(L)
        tree["cross"] = {
            "k": PSpec(
                (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
            "v": PSpec(
                (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                ("layers", "batch", "seq_kv", None, None),
                init="zeros",
            ),
        }
    else:
        raise ValueError(cfg.family)
    return tree


def _num_shared_invocations(cfg: ModelConfig) -> int:
    if not cfg.shared_attn_every:
        return 0
    return cfg.num_layers // cfg.shared_attn_every


def _ssd_state_specs(cfg: ModelConfig, L: int, batch: int) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": PSpec(
            (L, batch, cfg.conv_kernel - 1, conv_dim),
            ("layers", "batch", None, None),
            init="zeros",
        ),
        "state": PSpec(
            (L, batch, cfg.mamba_heads, cfg.mamba_head_dim, cfg.ssm_state),
            ("layers", "batch", "heads", None, None),
            init="zeros",
            dtype=jnp.float32,
        ),
    }


# ---------------------------------------------------------------------------
# Metadata advance (once per step) + one-hot writes (per layer)
# ---------------------------------------------------------------------------


def advance_meta(cache: dict, positions: jax.Array, window: int | None) -> dict:
    """Update pos/valid/index for the S tokens being written this step."""
    if "pos" not in cache:
        return dict(cache, index=cache["index"] + positions.shape[1])
    T = cache["pos"].shape[1]
    S = S_consumed = positions.shape[1]
    if window is not None and S > T:
        # ring cache: only the last T tokens survive; slicing first keeps
        # slot writes unique (T consecutive positions mod T is a permutation)
        positions = positions[:, -T:]
        S = T
    slots = positions % T if window is not None else (
        cache["index"][:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    )
    oh = jax.nn.one_hot(slots, T, dtype=jnp.int32)  # (B, S, T)
    written = oh.sum(1)  # (B, T)
    pos = cache["pos"] * (1 - written) + jnp.einsum(
        "bst,bs->bt", oh, positions.astype(jnp.int32)
    )
    valid = cache["valid"] | (written > 0)
    return dict(cache, pos=pos, valid=valid, index=cache["index"] + S_consumed)


def _onehot_write(buf: jax.Array, new: jax.Array, slots: jax.Array) -> jax.Array:
    """buf: (B, T, ...); new: (B, S, ...); slots: (B, S) -> updated buf."""
    T = buf.shape[1]
    oh = jax.nn.one_hot(slots, T, dtype=buf.dtype)  # (B, S, T)
    keep = 1 - oh.sum(1)  # (B, T)
    keep = keep.reshape(keep.shape + (1,) * (buf.ndim - 2))
    add = jnp.einsum("bst,bs...->bt...", oh, new)
    return buf * keep + add


def _write_slots(meta_index, positions: jax.Array, T: int, window) -> jax.Array:
    if window is not None:
        return positions % T
    steps = jnp.arange(positions.shape[1], dtype=jnp.int32)
    return meta_index[:, None] + steps[None, :]


def update_kv_cache(cache: dict, k, v, positions, ctx):
    """Write new K/V (B, S, ...) and return full cache views + key metadata.

    ``cache`` is one layer's {"k", "v"} plus the step-level "_meta" dict
    (pos/valid/index *already advanced* for this step).
    """
    meta = cache["_meta"]
    T = cache["k"].shape[1]
    window = ctx.cfg.sliding_window
    S = positions.shape[1]
    if window is not None and S > T:  # ring: only the last T tokens survive
        k, v, positions = k[:, -T:], v[:, -T:], positions[:, -T:]
        S = T
    if S == T and window is None:
        new_k = k.astype(cache["k"].dtype)
        new_v = v.astype(cache["v"].dtype)
    else:
        slots = _write_slots(meta["index"] - S, positions, T, window)
        new_k = _onehot_write(cache["k"], k.astype(cache["k"].dtype), slots)
        new_v = _onehot_write(cache["v"], v.astype(cache["v"].dtype), slots)
    new_k = ctx.shard.constrain(new_k, "batch", "seq_kv", None, None)
    new_v = ctx.shard.constrain(new_v, "batch", "seq_kv", None, None)
    return {"k": new_k, "v": new_v}, new_k, new_v, meta["pos"], meta["valid"]


def update_mla_cache(cache: dict, c_kv, k_rope, positions, ctx):
    meta = cache["_meta"]
    T = cache["c_kv"].shape[1]
    S = positions.shape[1]
    if S == T:
        new_c = c_kv.astype(cache["c_kv"].dtype)
        new_r = k_rope.astype(cache["k_rope"].dtype)
    else:
        slots = _write_slots(meta["index"] - S, positions, T, None)
        new_c = _onehot_write(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), slots)
        new_r = _onehot_write(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), slots
        )
    new_c = ctx.shard.constrain(new_c, "batch", "seq_kv", None)
    new_r = ctx.shard.constrain(new_r, "batch", "seq_kv", None)
    return {"c_kv": new_c, "k_rope": new_r}, new_c, new_r, meta["pos"], meta["valid"]
