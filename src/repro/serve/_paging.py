"""Host-side page accounting for the paged KV cache.

The device side (``repro.serve._cache``) only routes writes through a
``page_table`` — it never allocates.  This module owns the physical page
pool: a free list, per-page refcounts, and a prompt-prefix registry that
backs copy-on-write prefix sharing.

Contracts (relied on by ``BatchingEngine`` and asserted in tests):

* **Refcounts.**  A page is owned by every slot row that maps it plus every
  registry entry that pins it; it returns to the free list exactly when the
  count hits zero (``retire`` / registry eviction).
* **Registry.**  Keys are *full-page-aligned* token prefixes (the raw int32
  bytes of ``prompt[:m * page_size]`` for every m); values are the physical
  pages holding exactly those tokens.  Entries are registered after the
  prefill that writes them was issued, so a hit always references fully
  written, immutable pages: registered pages cover only whole prompt pages
  (group < plen // page_size) and decode writes start at group
  ``plen // page_size`` — a shared page is never written again in place.
* **Copy-on-write.**  When a hit covers the entire prompt, the final token
  still needs its logits, so the last matched page is *duplicated* into a
  private page (the copy pair in :class:`AdmitPlan`) and the tail — at
  least one token — is re-prefilled over the copy.  This is the only case
  where a write would target a shared page, and it targets the copy.
* **Exhaustion.**  Allocation first evicts registry entries (oldest first);
  if the pool is still dry, :class:`PagePoolExhausted` propagates — the
  engine defers admission or surfaces ``CacheOverflowError`` mid-decode.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class PagePoolExhausted(RuntimeError):
    """No free physical page, even after evicting the prefix registry."""


@dataclasses.dataclass
class AdmitPlan:
    """Host-side admission outcome: prefill starts at logical token
    ``start`` (everything before it is mapped from shared pages), with at
    most one COW page duplication (``copy_src -> copy_dst``, -1 = none)."""

    slot: int
    start: int
    copy_src: int = -1
    copy_dst: int = -1


def _prefix_key(prompt: np.ndarray, n_tokens: int) -> bytes:
    return np.ascontiguousarray(prompt[:n_tokens], dtype=np.int32).tobytes()


class PageAllocator:
    def __init__(
        self,
        num_pages: int,
        page_size: int,
        num_slots: int,
        pages_per_slot: int,
        share: bool = True,
    ):
        self.num_pages, self.page_size = num_pages, page_size
        self.pages_per_slot = pages_per_slot
        self.share = share
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> page 0 first
        self._ref = np.zeros((num_pages,), np.int64)
        # the slot→page map mirrored on host; uploaded before each step
        self.table = np.full((num_slots, pages_per_slot), -1, np.int32)
        self._registry: dict[bytes, tuple[int, ...]] = {}  # insertion-ordered

    # -- pool primitives ----------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def _alloc(self) -> int:
        if not self._free:
            raise PagePoolExhausted(
                f"all {self.num_pages} physical pages are referenced"
            )
        p = self._free.pop()
        self._ref[p] = 1
        return p

    def _retain(self, page: int) -> None:
        self._ref[page] += 1

    def _release(self, page: int) -> None:
        self._ref[page] -= 1
        assert self._ref[page] >= 0, f"refcount underflow on page {page}"
        if self._ref[page] == 0:
            self._free.append(page)

    def _evict_one(self) -> bool:
        """Drop the oldest registry entry (its pages free once no active
        slot maps them)."""
        if not self._registry:
            return False
        key = next(iter(self._registry))
        for p in self._registry.pop(key):
            self._release(p)
        return True

    def _reserve(self, n: int) -> bool:
        while len(self._free) < n:
            if not self._evict_one():
                return False
        return True

    # -- admission / decode / retire ----------------------------------------

    def has_prefix(self, key: bytes) -> bool:
        return key in self._registry

    def lookup(self, prompt: np.ndarray) -> tuple[int, ...]:
        """Longest registered full-page prefix of ``prompt`` (may be ())."""
        if not self.share:
            return ()
        for m in range(len(prompt) // self.page_size, 0, -1):
            hit = self._registry.get(_prefix_key(prompt, m * self.page_size))
            if hit is not None:
                return hit
        return ()

    def admit(self, slot: int, prompt: np.ndarray) -> AdmitPlan | None:
        """Map shared prefix pages into ``slot`` and allocate pages for the
        divergent tail; returns None (nothing mutated) when the pool cannot
        cover the tail even after registry eviction."""
        plen = len(prompt)
        ps = self.page_size
        row = self.table[slot]
        assert (row < 0).all(), f"slot {slot} was not retired before re-admission"
        shared = self.lookup(prompt)
        # always re-prefill at least the final token: its logits seed decode
        start = min(len(shared) * ps, plen - 1)
        g_full, rem = divmod(start, ps)
        # retain the match before reserving: eviction must not free (and
        # recycle) the very pages we are about to map
        for p in shared[:g_full + (1 if rem else 0)]:
            self._retain(p)
        n_fresh = (plen - 1) // ps - g_full + 1 if plen else 0
        if not self._reserve(n_fresh):
            for p in shared[:g_full + (1 if rem else 0)]:
                self._release(p)
            return None
        row[:g_full] = shared[:g_full]
        plan = AdmitPlan(slot=slot, start=start)
        g0 = g_full
        if rem:  # COW: duplicate the partially reused page, rewrite its tail
            dst = self._alloc()
            row[g_full] = dst
            plan.copy_src, plan.copy_dst = shared[g_full], dst
            self._release(shared[g_full])  # retained above only to pin it
            g0 += 1
        for g in range(g0, (plen - 1) // ps + 1):
            row[g] = self._alloc()
        return plan

    def admit_windowed(self, slot: int) -> AdmitPlan | None:
        """Ring caches reuse every page cyclically: map the full budget up
        front (sharing is disabled — ring contents are position-dependent)."""
        row = self.table[slot]
        assert (row < 0).all(), f"slot {slot} was not retired before re-admission"
        if not self._reserve(self.pages_per_slot):
            return None
        for g in range(self.pages_per_slot):
            row[g] = self._alloc()
        return AdmitPlan(slot=slot, start=0)

    def register(self, slot: int, prompt: np.ndarray) -> None:
        """Pin ``slot``'s full prompt pages under their prefix keys (call
        after the prefill writing them has been issued)."""
        if not self.share:
            return
        row = self.table[slot]
        for m in range(1, len(prompt) // self.page_size + 1):
            key = _prefix_key(prompt, m * self.page_size)
            if key in self._registry:
                continue
            pages = tuple(int(p) for p in row[:m])
            if any(p < 0 for p in pages):
                return
            for p in pages:
                self._retain(p)
            self._registry[key] = pages

    def ensure_page(self, slot: int, t: int) -> bool:
        """Map a page for the decode write at logical position ``t`` if its
        group is unmapped; returns True when the table changed."""
        capacity = self.pages_per_slot * self.page_size
        g = (t % capacity) // self.page_size
        if self.table[slot, g] >= 0:
            return False
        if not self._reserve(1):
            raise PagePoolExhausted(
                f"slot {slot} needs a page for position {t} but all "
                f"{self.num_pages} pages are referenced"
            )
        self.table[slot, g] = self._alloc()
        return True

    def retire(self, slot: int) -> None:
        """Release every page the slot maps (frees them at refcount zero;
        registry pins keep shared prefixes warm for future admissions)."""
        row = self.table[slot]
        for g in range(self.pages_per_slot):
            if row[g] >= 0:
                self._release(int(row[g]))
                row[g] = -1

    def release_prefixes(self) -> None:
        """Drop every registry pin (e.g. engine shutdown/tests)."""
        while self._evict_one():
            pass
