"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Layers are stacked along a leading ``layers`` axis and executed with
``lax.scan`` — one compiled block body regardless of depth (keeps the
40-cell x 2-mesh dry-run tractable; also how MaxText ships).  The scan body
is wrapped in ``jax.checkpoint`` per the ExecCfg remat policy.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import Ctx
from repro.models.moe import moe_ffn, moe_specs
from repro.models.params import PSpec, tree_map_specs


def stack_specs(tree, n: int):
    """Prepend a (n,)+"layers" axis to every PSpec in a block's tree."""
    return tree_map_specs(
        lambda s: PSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale, s.dtype),
        tree,
    )


def block_specs(cfg: ModelConfig) -> dict:
    attn = L.mla_specs(cfg) if cfg.attention == "mla" else L.attention_specs(cfg)
    ffn = moe_specs(cfg) if cfg.num_experts else L.mlp_specs(cfg)
    return {
        "ln1": L.norm_spec(cfg),
        "attn": attn,
        "ln2": L.norm_spec(cfg),
        "ffn": ffn,
    }


def decoder_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s: dict[str, Any] = {
        "embed": PSpec((cfg.padded_vocab, d), ("vocab", "embed"), init="embed"),
        "blocks": stack_specs(block_specs(cfg), cfg.num_layers),
        "ln_f": L.norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = L.linear_spec(d, cfg.padded_vocab, axes=("embed", "vocab"))
    return s


def embed_tokens(params: dict, tokens: jax.Array, ctx: Ctx) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    return ctx.shard.constrain(x, "batch", None, None)


def lm_logits(params: dict, x: jax.Array, ctx: Ctx) -> jax.Array:
    if ctx.cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = L.linear(params["lm_head"], x, ctx)
    return ctx.shard.constrain(logits, "batch", None, "vocab")


def _block_apply(p, x, ctx: Ctx, positions, layer_cache, meta):
    cfg = ctx.cfg
    h = L.apply_norm(p["ln1"], x, cfg)
    if cfg.attention == "mla":
        cache_in = dict(layer_cache, _meta=meta) if layer_cache else None
        h, new_cache = L.mla_attention(p["attn"], h, ctx, positions, cache=cache_in)
    else:
        cache_in = dict(layer_cache, _meta=meta) if layer_cache else None
        h, new_cache = L.attention(p["attn"], h, ctx, positions, cache=cache_in)
    x = x + h
    h = L.apply_norm(p["ln2"], x, cfg)
    if cfg.num_experts:
        h, aux = moe_ffn(p["ffn"], h, ctx)
    else:
        h, aux = L.mlp(p["ffn"], h, ctx), jnp.zeros((), jnp.float32)
    return x + h, new_cache, aux


def scan_blocks(params_blocks, x, ctx: Ctx, positions, cache_layers, meta):
    """Run the stacked blocks; returns (x, new_cache_layers, aux_sum)."""

    def body(carry, xs):
        lp, lc = xs
        out, new_c, aux = _block_apply(lp, carry, ctx, positions, lc, meta)
        return out, (new_c if new_c is not None else {}, aux)

    if ctx.ex.remat != "none":
        body = jax.checkpoint(body, policy=_remat_policy(ctx.ex.remat))
    xs = (params_blocks, cache_layers if cache_layers is not None else {})
    x, (new_caches, auxs) = jax.lax.scan(
        body, x, xs, unroll=True if ctx.ex.inner_unroll else 1
    )
    return x, (new_caches if cache_layers is not None else None), jnp.sum(auxs)


def _remat_policy(name: str):
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if name == "dots_no_batch":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return None  # "full": save nothing


def forward(
    params: dict,
    tokens: jax.Array,  # (B, S) int32
    ctx: Ctx,
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
    embeds: Optional[jax.Array] = None,  # VLM: (B, S_img, d) patch embeddings
    token_mask: Optional[jax.Array] = None,  # (B, S) bool: real (unpadded) tokens
):
    """Returns (logits, new_cache, aux_loss).

    ``token_mask`` marks real tokens in a right-padded batch (the serving
    engine's batched multi-slot prefill): masked positions write nothing
    into the cache and do not advance the per-slot index, so rows whose
    mask is all-False pass through with their cache state untouched.
    """
    from repro.serve._cache import advance_meta

    x = embed_tokens(params, tokens, ctx)
    if embeds is not None:  # VLM: image tokens first (llava layout)
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        x = ctx.shard.constrain(x, "batch", None, None)
        if token_mask is not None:  # image tokens count as real tokens
            img = jnp.ones((x.shape[0], embeds.shape[1]), bool)
            token_mask = jnp.concatenate([img, token_mask], axis=1)
    B, S, _ = x.shape
    if positions is None:
        if cache is not None:
            steps = jnp.arange(S, dtype=jnp.int32)[None, :]
            positions = cache["index"][:, None] + steps
        else:
            steps = jnp.arange(S, dtype=jnp.int32)[None, :]
            positions = jnp.broadcast_to(steps, (B, S))

    meta = None
    new_cache = None
    cache_layers = None
    if cache is not None:
        cache, meta = advance_meta(
            cache, positions, ctx.cfg.sliding_window, token_mask
        )
        cache_layers = cache["layers"]

    x, new_layers, aux = scan_blocks(
        params["blocks"], x, ctx, positions, cache_layers, meta
    )
    x = L.apply_norm(params["ln_f"], x, ctx.cfg)
    if ctx.ex.logits == "last":
        x = x[:, -1:]
    logits = lm_logits(params, x, ctx)
    if cache is not None:
        new_cache = dict(cache, layers=new_layers)
    return logits, new_cache, aux
