"""Model registry: family -> (specs, forward) dispatch.

The unified contract every family implements:
  model_specs(cfg)                          -> PSpec tree
  model_forward(params, batch_inputs, ctx, cache=None) -> (logits, cache, aux)
where batch inputs are {"tokens", and optionally "embeds" (VLM patch
embeddings) / "enc_embeds" (audio frame embeddings)} — the modality
frontends are stubs per the assignment.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx


def model_specs(cfg: ModelConfig) -> dict:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import decoder_specs

        return decoder_specs(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import hybrid_specs

        return hybrid_specs(cfg)
    if cfg.family == "ssm":
        from repro.models.rwkv import rwkv_lm_specs

        return rwkv_lm_specs(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import encdec_specs

        return encdec_specs(cfg)
    raise ValueError(cfg.family)


def model_forward(
    params: dict,
    inputs: dict[str, jax.Array],
    ctx: Ctx,
    cache: Optional[dict] = None,
):
    """Returns (logits, new_cache, aux_loss).

    ``inputs`` holds "tokens" plus optional modality extras and, for the
    serving engine's batched multi-slot prefill, "token_mask" — a (B, S)
    bool marking real (unpadded) tokens.  Masked cache writes are only
    defined for one-hot KV ring caches, so "token_mask" is limited to the
    attention families; recurrent-state families (hybrid/ssm) reject it.
    """
    cfg = ctx.cfg
    tokens = inputs["tokens"]
    token_mask = inputs.get("token_mask")
    if token_mask is not None and cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"token_mask (masked batched prefill) is not supported for "
            f"family {cfg.family!r}: its recurrent/cross caches have no "
            "slot-targeted write form"
        )
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import forward

        return forward(
            params,
            tokens,
            ctx,
            cache=cache,
            embeds=inputs.get("embeds"),
            token_mask=token_mask,
        )
    if cfg.family == "hybrid":
        from repro.models.hybrid import forward

        return forward(params, tokens, ctx, cache=cache)
    if cfg.family == "ssm":
        from repro.models.rwkv import forward

        return forward(params, tokens, ctx, cache=cache)
    if cfg.family == "encdec":
        from repro.models.encdec import forward

        return forward(
            params, tokens, ctx, enc_embeds=inputs.get("enc_embeds"), cache=cache
        )
    raise ValueError(cfg.family)
