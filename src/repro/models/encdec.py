"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: the model consumes
precomputed frame embeddings (B, S_enc, d) from ``input_specs()``.  Encoder
is bidirectional with sinusoidal positions; decoder is causal self-attention
+ cross-attention to the encoder output.  At serve time the cross K/V are
computed once at prefill and cached (they are static thereafter).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import Ctx
from repro.models.params import PSpec
from repro.models.transformer import _remat_policy, stack_specs


def encdec_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    enc_block = {
        "ln1": L.norm_spec(cfg),
        "attn": L.attention_specs(cfg),
        "ln2": L.norm_spec(cfg),
        "mlp": L.mlp_specs(cfg),
    }
    dec_block = {
        "ln1": L.norm_spec(cfg),
        "self_attn": L.attention_specs(cfg),
        "ln_x": L.norm_spec(cfg),
        "cross_attn": L.attention_specs(cfg),
        "ln2": L.norm_spec(cfg),
        "mlp": L.mlp_specs(cfg),
    }
    return {
        "embed": PSpec((cfg.padded_vocab, d), ("vocab", "embed"), init="embed"),
        "encoder": stack_specs(enc_block, cfg.encoder_layers),
        "enc_ln_f": L.norm_spec(cfg),
        "decoder": stack_specs(dec_block, cfg.num_layers),
        "ln_f": L.norm_spec(cfg),
        # whisper ties the output head to the token embedding
    }


def encode(params: dict, enc_embeds: jax.Array, ctx: Ctx) -> jax.Array:
    cfg = ctx.cfg
    B, S, d = enc_embeds.shape
    x = enc_embeds + L.sinusoidal_embedding(S, d)[None].astype(enc_embeds.dtype)
    x = ctx.shard.constrain(x, "batch", None, None)

    # bidirectional self-attention (full-visibility mask)
    def enc_attn_body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, cfg)
        yq, yk, yv = L.fused_linears(lp["attn"], ("wq", "wk", "wv"), h, ctx)
        q = L._split_heads(yq, cfg.num_heads)
        k = L._split_heads(yk, cfg.num_kv_heads)
        v = L._split_heads(yv, cfg.num_kv_heads)
        if ctx.shard.heads_shardable(cfg.num_heads):
            q = ctx.shard.constrain(q, "batch", None, "heads", None)
            k = ctx.shard.constrain(k, "batch", None, "kv_heads", None)
            v = ctx.shard.constrain(v, "batch", None, "kv_heads", None)
        else:  # whisper's 8 heads don't shard 16-way: shard query positions
            q = ctx.shard.constrain(q, "batch", "qseq", None, None)
        mask = jnp.ones((B, 1, S, S), bool)
        o = L._sdpa(q, k, v, mask, ctx)
        wo_out = L.linear(lp["attn"]["wo"], o, ctx)
        x2 = carry + ctx.shard.constrain(wo_out, "batch", None, None)
        return x2 + L.mlp(lp["mlp"], L.apply_norm(lp["ln2"], x2, cfg), ctx), None

    fn = enc_attn_body
    if ctx.ex.remat != "none":
        fn = jax.checkpoint(fn, policy=_remat_policy(ctx.ex.remat))
    x, _ = jax.lax.scan(fn, x, params["encoder"],
                        unroll=True if ctx.ex.inner_unroll else 1)
    return L.apply_norm(params["enc_ln_f"], x, cfg)


def _cross_kv_from(params_layer: dict, enc_out: jax.Array, ctx: Ctx):
    yk, yv = L.fused_linears(params_layer, ("wk", "wv"), enc_out, ctx)
    k = L._split_heads(yk, ctx.cfg.num_kv_heads)
    v = L._split_heads(yv, ctx.cfg.num_kv_heads)
    return k, v


def decode_blocks(
    params, x, ctx: Ctx, positions, cache_layers, meta, enc_out, cross_cache=None
):
    cfg = ctx.cfg

    def body(carry, xs):
        lp, lc, cc = xs
        h = L.apply_norm(lp["ln1"], carry, cfg)
        cache_in = dict(lc, _meta=meta) if lc else None
        h, new_c = L.attention(lp["self_attn"], h, ctx, positions, cache=cache_in)
        x2 = carry + h
        h = L.apply_norm(lp["ln_x"], x2, cfg)
        if cc:  # serve path: static cross K/V from the cache
            ckv = (cc["k"], cc["v"])
        else:  # train path: recompute from encoder output
            ckv = _cross_kv_from(lp["cross_attn"], enc_out, ctx)
        h, _ = L.attention(lp["cross_attn"], h, ctx, positions, cross_kv=ckv)
        x2 = x2 + h
        x2 = x2 + L.mlp(lp["mlp"], L.apply_norm(lp["ln2"], x2, cfg), ctx)
        return x2, (new_c if new_c is not None else {})

    if ctx.ex.remat != "none":
        body = jax.checkpoint(body, policy=_remat_policy(ctx.ex.remat))
    xs = (
        params["decoder"],
        cache_layers if cache_layers is not None else {},
        cross_cache if cross_cache is not None else {},
    )
    x, new_caches = jax.lax.scan(body, x, xs, unroll=True if ctx.ex.inner_unroll else 1)
    return x, (new_caches if cache_layers is not None else None)


def forward(
    params: dict,
    tokens: jax.Array,  # (B, S_dec)
    ctx: Ctx,
    enc_embeds: Optional[jax.Array] = None,  # (B, S_enc, d); None at decode
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
):
    from repro.serve._cache import advance_meta

    cfg = ctx.cfg
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if positions is None:
        start = cache["index"][:, None] if cache is not None else 0
        positions = jnp.broadcast_to(
            start + jnp.arange(S, dtype=jnp.int32)[None], (B, S)
        )
    # decoder positions: sinusoidal lookup at absolute positions (stands in
    # for whisper's learned table — see DESIGN.md §5)
    pos_emb = _sinusoidal_at(positions, cfg.d_model).astype(x.dtype)
    x = ctx.shard.constrain(x + pos_emb, "batch", None, None)

    meta, cache_layers, cross_cache, enc_out = None, None, None, None
    if cache is not None:
        cache, meta = advance_meta(cache, positions, None)
        cache_layers = cache["layers"]
        cross_cache = cache["cross"]
        if enc_embeds is not None:  # prefill: fill the cross cache
            enc_out = encode(params, enc_embeds, ctx)
            ks, vs = [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[i], params["decoder"])
                k, v = _cross_kv_from(lp["cross_attn"], enc_out, ctx)
                ks.append(k), vs.append(v)
            cross_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    else:
        assert enc_embeds is not None
        enc_out = encode(params, enc_embeds, ctx)

    x, new_layers = decode_blocks(
        params, x, ctx, positions, cache_layers, meta, enc_out, cross_cache
    )
    x = L.apply_norm(params["ln_f"], x, cfg)
    if ctx.ex.logits == "last":
        x = x[:, -1:]
    logits = x @ params["embed"].T  # tied head
    logits = ctx.shard.constrain(logits, "batch", None, "vocab")
    new_cache = None
    if cache is not None:
        new_cache = dict(cache, layers=new_layers, cross=cross_cache)
    return logits, new_cache, jnp.zeros((), jnp.float32)


def _sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
