"""The paper's own example networks: linear classifier, 784-1024-512-10 MLP,
and the LeNet-style CNN from the TF tutorial — built on the same ``linear``
abstraction as the LM zoo so the TableNet conversion pass applies verbatim.

Convolutions are expressed as im2col + linear: the weight matrix is shared
across spatial positions, which *is* the paper's "same LUT for every chunk,
output shifted and added" convolution scheme (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Ctx, linear, linear_spec


def linear_classifier_specs() -> dict:
    return {"fc": linear_spec(784, 10, axes=(None, None), bias=True)}


def linear_classifier_forward(params, images, ctx: Ctx):
    """images: (B, 28, 28) in [0, 1] -> logits (B, 10)."""
    x = images.reshape(images.shape[0], -1)
    return linear(params["fc"], x, ctx)


def mlp_specs() -> dict:
    return {
        "fc1": linear_spec(784, 1024, axes=(None, None), bias=True),
        "fc2": linear_spec(1024, 512, axes=(None, None), bias=True),
        "fc3": linear_spec(512, 10, axes=(None, None), bias=True),
    }


def mlp_forward(params, images, ctx: Ctx):
    x = images.reshape(images.shape[0], -1)
    x = jax.nn.relu(linear(params["fc1"], x, ctx))
    x = jax.nn.relu(linear(params["fc2"], x, ctx))
    return linear(params["fc3"], x, ctx)


# ---------------------------------------------------------------------------
# LeNet-style CNN (conv 5x5x32 -> pool -> conv 5x5x64 -> pool -> fc -> fc)
# ---------------------------------------------------------------------------


def im2col(x: jax.Array, k: int) -> jax.Array:
    """(B, H, W, C) -> (B, H, W, k*k*C) 'same' patches (zero-padded)."""
    B, H, W, C = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = [xp[:, i : i + H, j : j + W, :] for i in range(k) for j in range(k)]
    return jnp.concatenate(cols, axis=-1)


def maxpool2(x: jax.Array) -> jax.Array:
    B, H, W, C = x.shape
    return x.reshape(B, H // 2, 2, W // 2, 2, C).max(axis=(2, 4))


def lenet_specs() -> dict:
    return {
        "conv1": linear_spec(25, 32, axes=(None, None), bias=True),
        "conv2": linear_spec(25 * 32, 64, axes=(None, None), bias=True),
        "fc1": linear_spec(3136, 1024, axes=(None, None), bias=True),
        "fc2": linear_spec(1024, 10, axes=(None, None), bias=True),
    }


def lenet_forward(params, images, ctx: Ctx):
    """images: (B, 28, 28) -> logits (B, 10)."""
    x = images[..., None]  # (B, 28, 28, 1)
    x = jax.nn.relu(linear(params["conv1"], im2col(x, 5), ctx))
    x = maxpool2(x)  # (B, 14, 14, 32)
    x = jax.nn.relu(linear(params["conv2"], im2col(x, 5), ctx))
    x = maxpool2(x)  # (B, 7, 7, 64)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(linear(params["fc1"], x, ctx))
    return linear(params["fc2"], x, ctx)


PAPER_MODELS = {
    "linear": (linear_classifier_specs, linear_classifier_forward),
    "mlp": (mlp_specs, mlp_forward),
    "lenet": (lenet_specs, lenet_forward),
}
