"""Zamba2-style hybrid: Mamba2 backbone + a *shared-weight* attention+MLP
block invoked between segments of SSD layers.

Layout for L mamba layers with cadence ``shared_attn_every = g``:
  [g mamba] shared [g mamba] shared ... [remainder mamba]
Each shared-block invocation has its own KV cache slot (weights are shared,
activations are not).  Segments use static slices of the stacked mamba
params, so each segment is one lax.scan over its g layers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import Ctx
from repro.models.params import PSpec
from repro.models.ssm import mamba_block, mamba_specs
from repro.models.transformer import _remat_policy, embed_tokens, lm_logits, stack_specs


def segments(cfg: ModelConfig) -> list[int]:
    g = cfg.shared_attn_every
    L_ = cfg.num_layers
    segs = [g] * (L_ // g)
    if L_ % g:
        segs.append(L_ % g)
    return segs


def hybrid_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = {
        "embed": PSpec((cfg.padded_vocab, d), ("vocab", "embed"), init="embed"),
        "mamba": stack_specs(
            {"ln": L.norm_spec(cfg), "mix": mamba_specs(cfg)}, cfg.num_layers
        ),
        "shared": {
            "ln1": L.norm_spec(cfg),
            "attn": L.attention_specs(cfg),
            "ln2": L.norm_spec(cfg),
            "mlp": L.mlp_specs(cfg),
        },
        "ln_f": L.norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = L.linear_spec(d, cfg.padded_vocab, axes=("embed", "vocab"))
    return s


def _mamba_segment(params_slice, x, ctx: Ctx, cache_slice):
    def body(carry, xs):
        lp, lc = xs
        h, new_c = mamba_block(
            lp["mix"],
            L.apply_norm(lp["ln"], carry, ctx.cfg),
            ctx,
            cache=lc if lc else None,
        )
        return carry + h, (new_c if new_c is not None else {})

    if ctx.ex.remat != "none":
        body = jax.checkpoint(body, policy=_remat_policy(ctx.ex.remat))
    xs = (params_slice, cache_slice if cache_slice is not None else {})
    return jax.lax.scan(body, x, xs, unroll=True if ctx.ex.inner_unroll else 1)


def forward(
    params: dict,
    tokens: jax.Array,
    ctx: Ctx,
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
    embeds=None,
):
    from repro.serve._cache import advance_meta

    cfg = ctx.cfg
    x = embed_tokens(params, tokens, ctx)
    B, S, _ = x.shape
    if positions is None:
        start = cache["index"][:, None] if cache is not None else 0
        positions = jnp.broadcast_to(
            start + jnp.arange(S, dtype=jnp.int32)[None, :], (B, S)
        )

    meta, shared_kv, mamba_cache = None, None, None
    if cache is not None:
        cache, meta = advance_meta(cache, positions, None)
        shared_kv = cache["shared_attn"]
        mamba_cache = cache["layers"]

    segs = segments(cfg)
    new_mamba, new_shared = [], []
    start = 0
    for i, g in enumerate(segs):
        p_slice = jax.tree.map(lambda a: a[start : start + g], params["mamba"])
        c_slice = (
            jax.tree.map(lambda a: a[start : start + g], mamba_cache)
            if mamba_cache is not None
            else None
        )
        x, seg_cache = _mamba_segment(p_slice, x, ctx, c_slice)
        if mamba_cache is not None:
            new_mamba.append(seg_cache)
        start += g
        if i < len(segs) - 1 and cfg.shared_attn_every:
            sp = params["shared"]
            lc = None
            if shared_kv is not None:
                lc = dict(jax.tree.map(lambda a: a[i], shared_kv), _meta=meta)
            h, new_kv = L.attention(
                sp["attn"], L.apply_norm(sp["ln1"], x, cfg), ctx, positions, cache=lc
            )
            x = x + h
            x = x + L.mlp(sp["mlp"], L.apply_norm(sp["ln2"], x, cfg), ctx)
            if shared_kv is not None:
                new_shared.append(new_kv)

    x = L.apply_norm(params["ln_f"], x, cfg)
    if ctx.ex.logits == "last":
        x = x[:, -1:]
    logits = lm_logits(params, x, ctx)
    new_cache = None
    if cache is not None:
        new_cache = dict(
            cache,
            layers=jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
            shared_attn=(
                jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_shared)
                if new_shared else cache["shared_attn"]
            ),
        )
    return logits, new_cache, jnp.zeros((), jnp.float32)
