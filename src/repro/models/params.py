"""Parameter-spec system: models declare shapes + logical axes, the runtime
materialises arrays (smoke tests / real training) or ShapeDtypeStructs with
shardings attached (the multi-pod dry-run never allocates a byte).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[Any, ...]  # logical axis name (str) or None per dim
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # stddev override for "normal"
    dtype: Any = None  # None -> model default

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, PSpec)


def tree_map_specs(fn: Callable[[PSpec], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def _fan_in(spec: PSpec) -> int:
    # convention: last axis is the output axis of a projection
    if len(spec.shape) == 1:
        return 1
    return int(np.prod(spec.shape[:-1]))


def init_params(tree, key: jax.Array, default_dtype=jnp.float32):
    """Materialise real arrays (used by smoke tests, examples, training)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(spec: PSpec, k):
        dtype = spec.dtype or default_dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        std = spec.scale
        if std is None:
            std = 0.02 if spec.init == "embed" else 1.0 / math.sqrt(_fan_in(spec))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return treedef.unflatten([one(s, k) for s, k in zip(leaves, keys)])


def abstract_params(tree, default_dtype=jnp.float32, sharding_fn=None):
    """ShapeDtypeStruct stand-ins (optionally with shardings) — no allocation."""

    def one(spec: PSpec):
        dtype = spec.dtype or default_dtype
        sharding = sharding_fn(spec) if sharding_fn is not None else None
        return jax.ShapeDtypeStruct(spec.shape, dtype, sharding=sharding)

    return tree_map_specs(one, tree)


def count_params(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_spec):
        total += int(np.prod(leaf.shape))
    return total
