"""Shared model layers: norms, RoPE, linears (with TableNet exec modes),
attention (GQA / sliding-window / MLA / cross) for both full-sequence and
cached-decode paths, and MLPs.

Every projection goes through :func:`linear` (or :func:`fused_linears` for
sibling projections over one input), which is where the paper's technique
plugs into the zoo: converted parameter trees carry ``core.convert``
``LUTLinear`` / pre-stacked ``LUTGroup`` nodes — each with its conversion
plan attached as static metadata — and execute via the LUT path (jnp
oracle under GSPMD, the Pallas kernel on real single-device runs);
``binary_matmul`` mode runs the beyond-paper bitplane-MXU path against the
original weights.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.convert import LUTGroup, LUTLinear
from repro.core.lut import LUTPlan, apply_luts, pack_codes, plane_scales
from repro.core.lut_tl1 import TL1Plan, apply_tl1, quantize_acts
from repro.core.quantize import FixedPointFormat
from repro.dist.sharding import ShardCtx
from repro.kernels.common import check_acc_contract
from repro.models.params import PSpec


@dataclasses.dataclass(frozen=True)
class ExecCfg:
    """Static execution options (hashable; closed over by jitted steps)."""

    linear_mode: str = "standard"  # standard | lut_gather | onehot_mxu | binary_matmul
    lut_chunk: int = 2  # elements per LUT for converted layers
    lut_grouped: bool = False  # fuse same-shape converted projections (QKV/gate-up)
    fixed_bits: int = 8  # binary_matmul input format
    fixed_frac: int = 6
    use_pallas: bool = False  # Pallas kernels vs jnp oracles
    remat: str = "full"  # full | dots | dots_no_batch | none
    logits: str = "all"  # all | last (prefill: only the final position's head)
    inner_unroll: bool = False  # unroll chunk scans (cost-analysis probes)
    ssd_chunk: int = 0  # 0 = auto(64); hillclimb knob for the SSD scan
    ssd_bf16: bool = False  # bf16 intra-chunk SSD math (cumsums stay f32)


@dataclasses.dataclass(frozen=True)
class SampleCfg:
    """Static sampling options for the serving layer (hashable; closed over
    by the jitted prefill/decode steps — sampling runs fused on device).

    ``greedy`` is argmax; ``temperature`` divides logits by ``temperature``
    then draws categorically; ``top_k`` restricts to the ``top_k`` largest
    logits first.  Non-greedy modes need per-slot PRNG keys (the serving
    cache's ``slot_key`` leaf), folded with the slot's write ``index`` so a
    sampled stream depends only on (request key, position) — never on the
    admission schedule or engine step count.
    """

    mode: str = "greedy"  # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0


def sample_tokens(
    logits: jax.Array,  # (B, V)
    scfg: SampleCfg,
    keys: jax.Array | None = None,  # (B, 2) uint32 per-row PRNG keys
) -> jax.Array:
    """Draw one token per row under ``scfg``; returns (B,) int32."""
    if scfg.mode == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if keys is None:
        raise ValueError(f"sampling mode {scfg.mode!r} needs per-row PRNG keys")
    scaled = logits.astype(jnp.float32) / max(scfg.temperature, 1e-6)
    if scfg.mode == "top_k":
        if scfg.top_k <= 0:
            raise ValueError("top_k mode needs SampleCfg.top_k >= 1")
        kth = jax.lax.top_k(scaled, scfg.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    elif scfg.mode != "temperature":
        raise ValueError(f"unknown sampling mode {scfg.mode!r}")
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class Ctx:
    cfg: ModelConfig
    shard: ShardCtx = ShardCtx()
    ex: ExecCfg = ExecCfg()


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_spec(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    s = {"scale": PSpec((d,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        s["bias"] = PSpec((d,), ("embed",), init="zeros")
    return s


def apply_norm(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear with TableNet execution modes
# ---------------------------------------------------------------------------


def linear_spec(
    d_in: int, d_out: int, axes=("embed", "heads_flat"), bias: bool = False
) -> dict:
    s = {"w": PSpec((d_in, d_out), axes)}
    if bias:
        s["b"] = PSpec((d_out,), (axes[1],), init="zeros")
    return s


def _tl1_apply(
    tables: jax.Array,  # (kb, p) uint8 packed base-3 indices
    b: jax.Array | None,
    plan: "TL1Plan",
    x: jax.Array,
    ctx: Ctx,
    acts: tuple | None = None,  # pre-quantized (codes, act_scale)
    scale: jax.Array | None = None,  # ternary weight scale
) -> jax.Array:
    """One TL1-converted projection: per-token 9-entry activation LUT +
    packed ternary weight-pair indices (the activation-side table family)."""
    assert x.shape[-1] == plan.in_features, (x.shape, plan)
    # both execution paths accumulate int32 (fp32 on the exact variant) —
    # assert the plan's proved bound against that before dispatching.
    check_acc_contract(
        "lut_tl1", plan, "int32" if plan.act_bits is not None else "float32"
    )
    if acts is None:
        acts = quantize_acts(x, plan)
    codes, act_scale = acts
    if ctx.ex.use_pallas:
        from repro.kernels.lut_tl1.ops import lut_tl1

        y = lut_tl1(
            codes, tables, act_scale, scale, bias=b, blocks=plan.blocks, plan=plan
        )
    else:
        y = apply_tl1(tables, x, plan, bias=b, scale=scale, acts=acts)
    return y.astype(x.dtype)


def _lut_apply(
    tables: jax.Array,  # (k, entries, p)
    b: jax.Array | None,
    plan: LUTPlan,
    x: jax.Array,
    ctx: Ctx,
    codes: jax.Array | None = None,  # pre-packed (shared across a group)
    scales: jax.Array | None = None,
    scale: jax.Array | None = None,  # narrow-table dequant scale
) -> jax.Array:
    """One converted projection under the plan stored at conversion time
    (no shape sniffing — fixed-point and fp16 plans with colliding entry
    counts both execute correctly)."""
    ex = ctx.ex
    assert x.shape[-1] == plan.in_features, (x.shape, plan)
    check_acc_contract("lut_affine", plan, "float32")
    if codes is None:
        codes = pack_codes(x, plan)
    if scales is None:
        scales = jnp.asarray(plane_scales(plan), jnp.float32)
    if scale is not None:  # power-of-2 dequant folds into the plane scales
        scales = scales * scale
    shifted = plan.mode == "bitplane_shift"
    if ex.use_pallas:
        from repro.kernels.lut_affine.ops import lut_affine

        y = lut_affine(
            codes,
            tables,
            scales,
            bias=b,
            blocks=plan.blocks,
            shift_bits=plan.index_bits if shifted else 0,
            plan=plan,
        )
    elif ex.linear_mode == "onehot_mxu" and not shifted:
        # (bitplane_shift codes carry the exponent above the index bits, so
        # they cannot feed a one-hot of width num_entries — use the oracle.)
        onehot = jax.nn.one_hot(codes, plan.num_entries, dtype=jnp.bfloat16)
        per_plane = jnp.einsum(
            "...nke,kep->...np",
            onehot,
            tables.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
        y = jnp.einsum("...np,n->...p", per_plane, scales)
        if b is not None:
            y = y + b
    else:
        y = apply_luts(tables, codes, plan, bias=b, scales=scales)
    return y.astype(x.dtype)


def linear(p: dict | LUTLinear, x: jax.Array, ctx: Ctx) -> jax.Array:
    """y = x @ W (+ b), or its TableNet-converted equivalents."""
    ex = ctx.ex
    if isinstance(p, LUTLinear):  # converted layer: paper-faithful LUT path
        if isinstance(p.plan, TL1Plan):
            return _tl1_apply(p.tables, p.b, p.plan, x, ctx, scale=p.scale)
        return _lut_apply(p.tables, p.b, p.plan, x, ctx, scale=p.scale)
    b = p.get("b")
    if ex.linear_mode == "binary_matmul":  # beyond-paper MXU bitplane path
        fmt = FixedPointFormat(ex.fixed_bits, ex.fixed_frac, signed=True)
        plan = LUTPlan(x.shape[-1], p["w"].shape[-1], 1, fmt, mode="bitplane")
        codes = pack_codes(x, plan)  # (..., n, q) chunk=1 -> bits
        scales = jnp.asarray(plane_scales(plan), jnp.float32)
        if ex.use_pallas:
            from repro.kernels.binary_matmul.ops import binary_matmul

            y = binary_matmul(codes.astype(jnp.int8), p["w"], scales, bias=b)
        else:
            prod = jnp.einsum(
                "...nq,qp->...np",
                codes.astype(jnp.bfloat16),
                p["w"].astype(jnp.bfloat16),
                preferred_element_type=jnp.float32,
            )
            y = jnp.einsum("...np,n->...p", prod, scales)
            if b is not None:
                y = y + b
        return y.astype(x.dtype)
    y = x @ p["w"]
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _tl1_group_apply(
    node: LUTGroup,
    wanted: list[str],
    x: jax.Array,
    ctx: Ctx,
    acts: tuple | None = None,  # pre-quantized (shared across sibling groups)
):
    """TL1 twin of :func:`_group_apply`: the input is quantized ONCE for the
    whole group; when every member is wanted and ``ctx.ex.lut_grouped`` is
    set, the stored ``(G, kb, p)`` packed leaf feeds ``lut_tl1_grouped``
    (one Pallas dispatch) or a vmapped oracle.  Ternary scales are per
    member (``node.scale`` is ``(G,)``), applied after the accumulate."""
    plan = node.plan
    check_acc_contract(
        "lut_tl1_grouped", plan, "int32" if plan.act_bits is not None else "float32"
    )
    if acts is None:
        acts = quantize_acts(x, plan)
    codes, act_scale = acts
    fuse = len(wanted) == len(node.members) and ctx.ex.lut_grouped
    outs: dict[str, jax.Array] = {}
    if fuse:
        stacked_b = node.b if isinstance(node.b, jax.Array) else None
        if ctx.ex.use_pallas:
            from repro.kernels.lut_tl1.ops import lut_tl1_grouped

            y = lut_tl1_grouped(
                codes,
                node.tables,
                act_scale,
                node.scale,
                biases=stacked_b,
                blocks=plan.blocks,
                plan=plan,
            )
        else:
            y = jax.vmap(
                lambda t, s: apply_tl1(t, x, plan, scale=s, acts=acts)
            )(node.tables, node.scale)
            if stacked_b is not None:
                y = y + stacked_b.reshape(
                    stacked_b.shape[:1] + (1,) * (y.ndim - 2) + stacked_b.shape[-1:]
                )
        for g, name in enumerate(node.members):
            yi = y[g]
            if stacked_b is None and node.member_bias(g) is not None:
                yi = yi + node.member_bias(g)
            outs[name] = yi.astype(x.dtype)
        return outs
    for g, name in enumerate(node.members):
        if name in wanted:
            outs[name] = _tl1_apply(
                node.tables[g],
                node.member_bias(g),
                plan,
                x,
                ctx,
                acts=acts,
                scale=node.scale[..., g],
            )
    return outs


def _group_apply(
    node: LUTGroup,
    wanted: list[str],
    x: jax.Array,
    ctx: Ctx,
    codes: jax.Array | None = None,  # pre-packed (shared across sibling groups)
):
    """Execute (a subset of) a pre-stacked :class:`LUTGroup` against ``x``.

    The input is packed ONCE for the whole group.  When every member is
    wanted and ``ctx.ex.lut_grouped`` is set, the stored ``(G, k, E, p)``
    leaf feeds ``lut_affine_grouped`` (one Pallas dispatch) or a vmapped
    oracle gather directly — zero per-step stack/concat, the tables were
    stacked at conversion time.  Otherwise each wanted member indexes its
    ``tables[g]`` slice and runs the per-projection path (bit-identical:
    the grouped gather is just the vmap of the member gathers).
    ``onehot_mxu`` has no grouped equivalent (bf16 MXU math differs from
    the f32 gather), so that mode never fuses — identical-results wins
    over fusion.
    """
    plan = node.plan
    check_acc_contract("lut_affine_grouped", plan, "float32")
    if codes is None:
        codes = pack_codes(x, plan)
    scales = jnp.asarray(plane_scales(plan), jnp.float32)
    if node.scale is not None:  # shared dequant scale of the stacked leaf
        scales = scales * node.scale
    fuse = (
        len(wanted) == len(node.members)
        and ctx.ex.lut_grouped
        # onehot_mxu has no grouped equivalent — except under bitplane_shift,
        # whose exponent-carrying codes cannot feed a one-hot at all: there
        # every execution mode runs the same gather, so fusing stays exact.
        and (ctx.ex.linear_mode != "onehot_mxu" or plan.mode == "bitplane_shift")
    )
    outs: dict[str, jax.Array] = {}
    if fuse:
        stacked_b = node.b if isinstance(node.b, jax.Array) else None
        if ctx.ex.use_pallas:
            from repro.kernels.lut_affine.ops import lut_affine_grouped

            y = lut_affine_grouped(
                codes,
                node.tables,
                scales,
                biases=stacked_b,
                blocks=plan.blocks,
                plan=plan,
                shift_bits=plan.index_bits if plan.mode == "bitplane_shift" else 0,
            )
        else:
            y = jax.vmap(lambda t: apply_luts(t, codes, plan, scales=scales))(
                node.tables
            )
            if stacked_b is not None:
                y = y + stacked_b.reshape(
                    stacked_b.shape[:1] + (1,) * (y.ndim - 2) + stacked_b.shape[-1:]
                )
        for g, name in enumerate(node.members):
            yi = y[g]
            if stacked_b is None and node.member_bias(g) is not None:
                yi = yi + node.member_bias(g)
            outs[name] = yi.astype(x.dtype)
        return outs
    for g, name in enumerate(node.members):
        if name in wanted:
            outs[name] = _lut_apply(
                node.tables[g],
                node.member_bias(g),
                plan,
                x,
                ctx,
                codes=codes,
                scales=scales,
            )
    return outs


def fused_linears(
    parent: dict, names: Sequence[str], x: jax.Array, ctx: Ctx
) -> list[jax.Array]:
    """Apply the sibling projections ``names`` of ``parent`` to the *same*
    input, returning outputs in ``names`` order.

    Converted trees store fusable siblings as a single pre-stacked
    :class:`LUTGroup` node (under ``"wk+wv"``-style keys) — those are read
    directly (see :func:`_group_apply`); anything still stored per-name
    (dense weights, per-projection ``LUTLinear``) falls back to
    :func:`linear` member-wise, so the result is always elementwise
    identical to the unfused path.
    """
    outs: dict[str, jax.Array] = {}
    packed: dict[tuple, Any] = {}  # share packed codes across same-input groups
    for node in parent.values():
        if isinstance(node, LUTGroup):
            wanted = [m for m in node.members if m in names]
            if wanted:
                if isinstance(node.plan, TL1Plan):
                    # TL1 "packing" is activation quantization: share one
                    # (codes, act_scale) per input format across groups
                    key = ("tl1", node.plan.in_features, node.plan.act_bits)
                    if key not in packed:
                        packed[key] = quantize_acts(x, node.plan)
                    outs.update(
                        _tl1_group_apply(node, wanted, x, ctx, acts=packed[key])
                    )
                    continue
                key = (
                    "weight",
                    node.plan.in_features,
                    node.plan.chunk_size,
                    node.plan.mode,
                    node.plan.fmt,
                )
                if key not in packed:
                    packed[key] = pack_codes(x, node.plan)
                outs.update(_group_apply(node, wanted, x, ctx, codes=packed[key]))
    for name in names:
        if name not in outs:
            outs[name] = linear(parent[name], x, ctx)
    return [outs[name] for name in names]


def member_linear(parent: dict, name: str, x: jax.Array, ctx: Ctx) -> jax.Array:
    """One projection by name, whether stored per-name or inside a
    pre-stacked group (e.g. cross-attention's lone ``wq``)."""
    return fused_linears(parent, (name,), x, ctx)[0]


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) absolute indices."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA family)
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    bias = cfg.attn_bias
    return {
        "wq": linear_spec(d, cfg.num_heads * hd, bias=bias),
        "wk": linear_spec(d, cfg.num_kv_heads * hd, bias=bias),
        "wv": linear_spec(d, cfg.num_kv_heads * hd, bias=bias),
        "wo": linear_spec(cfg.num_heads * hd, d, axes=("heads_flat", "embed")),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    B, S, _ = x.shape
    return x.reshape(B, S, n, -1)


def _mask_bias(mask: jax.Array) -> jax.Array:
    return jnp.where(mask, 0.0, -1e9).astype(jnp.float32)


def _sdpa(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, K, hd)
    v: jax.Array,  # (B, Sk, K, hd)
    mask: jax.Array,  # (B, 1, Sq, Sk) or (B, 1, 1, Sk) boolean
    ctx: Ctx,
) -> jax.Array:
    """Grouped scaled-dot-product attention; returns (B, Sq, H*hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum(
        "bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    scores = scores + _mask_bias(mask)[:, :, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, Sq, H * hd)


def causal_mask(
    q_pos: jax.Array,  # (B, Sq)
    k_pos: jax.Array,  # (B, Sk)
    k_valid: jax.Array | None = None,  # (B, Sk) bool
    window: int | None = None,
) -> jax.Array:
    m = q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        m &= q_pos[:, :, None] - k_pos[:, None, :] < window
    if k_valid is not None:
        m &= k_valid[:, None, :]
    return m[:, None]  # (B, 1, Sq, Sk)


def attention(
    p: dict,
    x: jax.Array,
    ctx: Ctx,
    positions: jax.Array,
    cache: dict | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
    is_causal: bool = True,
):
    """Full-sequence (train/prefill) or cached-decode attention.

    Returns (out, new_cache).  ``cache`` layouts (dense and paged) are
    defined in ``repro.serve._cache``; updates use one-hot scatter so the
    sequence dim
    of the cache can stay sharded over the model axis (T5X-style — GSPMD
    partitions the one-hot contraction; no dynamic-slice-on-sharded-dim).
    """
    cfg, sh = ctx.cfg, ctx.shard
    B, S, _ = x.shape
    if cross_kv is None:
        yq, yk, yv = fused_linears(p, ("wq", "wk", "wv"), x, ctx)
        q = _split_heads(yq, cfg.num_heads)
        k = _split_heads(yk, cfg.num_kv_heads)
        v = _split_heads(yv, cfg.num_kv_heads)
        if cfg.pos == "rope":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    else:
        q = _split_heads(member_linear(p, "wq", x, ctx), cfg.num_heads)
        k, v = cross_kv
        if cfg.pos == "rope":
            q = rope(q, positions, cfg.rope_theta)

    heads_tp = sh.heads_shardable(cfg.num_heads) and sh.heads_shardable(
        cfg.num_kv_heads
    )
    new_cache = None
    if cache is not None and cross_kv is None and S == 1:
        # decode: attend over the cached keys
        from repro.serve._cache import update_kv_cache

        cache, k, v, k_pos, k_valid = update_kv_cache(cache, k, v, positions, ctx)
        new_cache = cache
        mask = causal_mask(positions, k_pos, k_valid, cfg.sliding_window)
        q = sh.constrain(q, "batch", None, "heads" if heads_tp else None, None)
    elif (
        cache is not None
        and cross_kv is None
        and cache["_meta"].page_ids is not None
        and cfg.sliding_window is None
    ):
        # paged prefill: attend through the page-table view — prefix
        # sharing maps already-written pages into this slot, so the
        # in-flight keys are not the whole visible context; the causal
        # mask (query positions start past the shared prefix) plus
        # ``valid`` exclude everything not written yet
        from repro.serve._cache import update_kv_cache

        new_cache, k, v, k_pos, k_valid = update_kv_cache(
            cache, k, v, positions, ctx
        )
        mask = causal_mask(positions, k_pos, k_valid)
        if heads_tp:
            q = sh.constrain(q, "batch", None, "heads", None)
        else:
            q = sh.constrain(q, "batch", "qseq", None, None)
    elif cache is not None and cross_kv is None:
        # prefill (fresh cache): attend over the in-flight keys — the ring
        # cache only retains the last `window` keys, which is state for
        # decode, not a valid view for early query positions
        from repro.serve._cache import update_kv_cache

        new_cache, _, _, _, _ = update_kv_cache(cache, k, v, positions, ctx)
        if heads_tp:
            q = sh.constrain(q, "batch", None, "heads", None)
            k = sh.constrain(k, "batch", None, "kv_heads", None)
            v = sh.constrain(v, "batch", None, "kv_heads", None)
        else:
            q = sh.constrain(q, "batch", "qseq", None, None)
        mask = causal_mask(positions, positions, None, cfg.sliding_window)
    else:
        if heads_tp:
            q = sh.constrain(q, "batch", None, "heads", None)
            k = sh.constrain(k, "batch", None, "kv_heads", None)
            v = sh.constrain(v, "batch", None, "kv_heads", None)
        elif S > 1:
            # fallback: shard query positions over the model axis; K/V are
            # gathered (sub-16-way head counts: DESIGN.md §4)
            q = sh.constrain(q, "batch", "qseq", None, None)
        if cross_kv is not None:
            mask = jnp.ones((B, 1, S, k.shape[1]), bool)
        else:
            mask = causal_mask(positions, positions, None, cfg.sliding_window)

    out = _sdpa(q, k, v, mask, ctx)
    out = linear(p["wo"], out, ctx)
    return sh.constrain(out, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# MLA attention (minicpm3 / deepseek-style latent KV)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    H = cfg.num_heads
    s = {
        "wq_a": linear_spec(d, cfg.q_lora_rank, axes=("embed", None)),
        "q_norm": {"scale": PSpec((cfg.q_lora_rank,), (None,), init="ones")},
        "wq_b": linear_spec(
            cfg.q_lora_rank, H * (nope + rdim), axes=(None, "heads_flat")
        ),
        "wkv_a": linear_spec(d, cfg.kv_lora_rank + rdim, axes=("embed", None)),
        "kv_norm": {"scale": PSpec((cfg.kv_lora_rank,), (None,), init="ones")},
        "wk_b": linear_spec(cfg.kv_lora_rank, H * nope, axes=(None, "heads_flat")),
        "wv_b": linear_spec(cfg.kv_lora_rank, H * vdim, axes=(None, "heads_flat")),
        "wo": linear_spec(H * vdim, d, axes=("heads_flat", "embed")),
    }
    return s


def _rms(x, scale, eps):
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


def mla_attention(
    p: dict,
    x: jax.Array,
    ctx: Ctx,
    positions: jax.Array,
    cache: dict | None = None,
):
    cfg, sh = ctx.cfg, ctx.shard
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q_lat_in = _rms(linear(p["wq_a"], x, ctx), p["q_norm"]["scale"], cfg.norm_eps)
    q = linear(p["wq_b"], q_lat_in, ctx)
    q = q.reshape(B, S, H, nope + rdim)
    # 40 heads don't shard 16-way: fall back to query-position sharding so
    # the (B, H, Sq, Sk) score tensors stay model-sharded (DESIGN.md §4)
    heads_tp = sh.heads_shardable(H)
    if S > 1:
        q = sh.constrain(
            q,
            "batch",
            None if heads_tp else "qseq",
            "heads" if heads_tp else None,
            None,
        )
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv = linear(p["wkv_a"], x, ctx)
    c_kv = _rms(kv[..., : cfg.kv_lora_rank], p["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = rope(
        kv[..., cfg.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0]  # (B, S, rdim) shared across heads

    if cache is not None and S == 1:
        from repro.serve._cache import update_mla_cache

        cache, c_kv_all, k_rope_all, k_pos, k_valid = update_mla_cache(
            cache, c_kv, k_rope, positions, ctx
        )
        mask = causal_mask(positions, k_pos, k_valid)
    elif cache is not None and cache["_meta"].page_ids is not None:
        # paged prefill: attend through the page-table view (prefix
        # sharing — see the GQA branch in :func:`attention`)
        from repro.serve._cache import update_mla_cache

        cache, c_kv_all, k_rope_all, k_pos, k_valid = update_mla_cache(
            cache, c_kv, k_rope, positions, ctx
        )
        mask = causal_mask(positions, k_pos, k_valid)
    elif cache is not None:  # prefill: write cache, attend in-flight
        from repro.serve._cache import update_mla_cache

        cache, _, _, _, _ = update_mla_cache(cache, c_kv, k_rope, positions, ctx)
        c_kv_all, k_rope_all = c_kv, k_rope
        mask = causal_mask(positions, positions)
    else:
        cache, c_kv_all, k_rope_all = None, c_kv, k_rope
        mask = causal_mask(positions, positions)

    # absorbed form: q_nope projected into latent space (decode-friendly)
    wk_b = p["wk_b"]["w"].reshape(cfg.kv_lora_rank, H, nope)
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, wk_b)  # (B, S, H, kv_lora)
    scores = (
        jnp.einsum(
            "bshl,btl->bhst", q_lat, c_kv_all, preferred_element_type=jnp.float32
        )
        + jnp.einsum(
            "bshr,btr->bhst", q_rope, k_rope_all, preferred_element_type=jnp.float32
        )
    ) / math.sqrt(nope + rdim)
    if S > 1:
        scores = sh.constrain(
            scores,
            "batch",
            "heads" if heads_tp else None,
            None if heads_tp else "qseq",
            None,
        )
    probs = jax.nn.softmax(scores + _mask_bias(mask), axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btl->bshl", probs, c_kv_all)  # (B, S, H, kv_lora)
    wv_b = p["wv_b"]["w"].reshape(cfg.kv_lora_rank, H, vdim)
    out = jnp.einsum("bshl,lhv->bshv", ctx_lat, wv_b).reshape(B, S, H * vdim)
    out = linear(p["wo"], out, ctx)
    return sh.constrain(out, "batch", None, None), cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act in ("gelu", "relu2"):  # 2-matrix MLP (whisper GELU, nemotron reluÂ²)
        return {
            "w_in": linear_spec(d, f, axes=("embed", "mlp"), bias=cfg.act == "gelu"),
            "w_out": linear_spec(f, d, axes=("mlp", "embed"), bias=cfg.act == "gelu"),
        }
    return {
        "w_gate": linear_spec(d, f, axes=("embed", "mlp")),
        "w_up": linear_spec(d, f, axes=("embed", "mlp")),
        "w_down": linear_spec(f, d, axes=("mlp", "embed")),
    }


def mlp(p: dict, x: jax.Array, ctx: Ctx) -> jax.Array:
    sh = ctx.shard
    if "w_in" in p:
        h = linear(p["w_in"], x, ctx)
        h = jnp.square(jax.nn.relu(h)) if ctx.cfg.act == "relu2" else jax.nn.gelu(h)
        h = sh.constrain(h, "batch", None, "mlp")
        return sh.constrain(linear(p["w_out"], h, ctx), "batch", None, None)
    g, u = fused_linears(p, ("w_gate", "w_up"), x, ctx)
    h = jax.nn.silu(g) * u
    h = sh.constrain(h, "batch", None, "mlp")
    return sh.constrain(linear(p["w_down"], h, ctx), "batch", None, None)
