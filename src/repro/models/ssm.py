"""Mamba2 (SSD) blocks — the zamba2 backbone.

Training/prefill uses the chunked SSD algorithm (Dao & Gu 2024, "minimal
mamba2" formulation): intra-chunk quadratic attention-like term + inter-chunk
state recurrence via an associative scan over chunk states.  Decode is the
O(1) recurrent update.  A naive recurrent reference lives in
``tests/test_ssm.py`` and the two must agree.

The SSD recurrence itself has *data-dependent* transition weights, so it is
not LUT-convertible (DESIGN.md §5); only the in/out projections participate
in TableNet conversion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, linear, linear_spec
from repro.models.params import PSpec


def mamba_specs(cfg: ModelConfig) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    H, N = cfg.mamba_heads, cfg.ssm_state
    conv_dim = din + 2 * N  # x, B, C share the causal conv (n_groups = 1)
    proj_out = 2 * din + 2 * N + H  # z, xBC, dt
    return {
        "in_proj": linear_spec(d, proj_out, axes=("embed", "heads_flat")),
        "conv_w": PSpec((cfg.conv_kernel, conv_dim), (None, "heads_flat")),
        "conv_b": PSpec((conv_dim,), ("heads_flat",), init="zeros"),
        "A_log": PSpec((H,), (None,), init="zeros"),
        "dt_bias": PSpec((H,), (None,), init="zeros"),
        "D": PSpec((H,), (None,), init="ones"),
        "norm_scale": PSpec((din,), (None,), init="ones"),
        "out_proj": linear_spec(din, d, axes=("heads_flat", "embed")),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) lower-triangular pairwise sums
    L[i, j] = sum_{t=j+1..i} x_t  (and -inf above the diagonal)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) post-softplus
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B, L, N)  (n_groups=1, shared across heads)
    Cm: jax.Array,  # (B, L, N)
    chunk: int = 64,
    init_state: jax.Array | None = None,  # (B, H, P, N)
    compute_dtype=jnp.float32,
):
    """Returns (y (B, L, H, P), final_state (B, H, P, N)).  Decay cumsums
    and the state carry stay f32; ``compute_dtype`` controls the big
    intra-chunk tensors (bf16 halves their bytes — hillclimb knob)."""
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    f32 = jnp.float32
    cd = compute_dtype
    xdt = (x.astype(f32) * dt.astype(f32)[..., None]).astype(cd).reshape(
        B_, nc, chunk, H, P
    )
    dA = (dt.astype(f32) * A.astype(f32)).reshape(B_, nc, chunk, H)
    Bc = Bm.astype(cd).reshape(B_, nc, chunk, N)
    Cc = Cm.astype(cd).reshape(B_, nc, chunk, N)

    dA_cs = jnp.cumsum(dA, axis=2)  # (B, nc, c, H) — f32 always

    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2))).astype(cd)  # (B,nc,H,c,c)
    att = jnp.einsum("bcin,bcjn,bchij->bchij", Cc, Bc, Lmat)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", att, xdt, preferred_element_type=f32)

    # --- chunk states ---
    decay_out = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs).astype(cd)  # (B, nc, c, H)
    states = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchpn", Bc, decay_out, xdt, preferred_element_type=f32
    )

    # --- inter-chunk recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (B, nc, H)
    s0 = (
        jnp.zeros((B_, H, P, N), f32)
        if init_state is None
        else init_state.astype(f32)
    )

    def step(s, inp):
        st, dec = inp  # (B, H, P, N), (B, H)
        nxt = s * dec[:, :, None, None] + st
        return nxt, s  # emit the state *entering* this chunk

    final, entering = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    entering = jnp.moveaxis(entering, 0, 1)  # (B, nc, H, P, N)

    decay_in = jnp.exp(dA_cs).astype(cd)  # (B, nc, c, H)
    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp",
        Cc,
        decay_in,
        entering.astype(cd),
        preferred_element_type=f32,
    )

    y = (y_diag.astype(f32) + y_inter).reshape(B_, L, H, P)
    return y, final


def ssd_decode_step(
    x: jax.Array,  # (B, 1, H, P)
    dt: jax.Array,  # (B, 1, H)
    A: jax.Array,
    Bm: jax.Array,  # (B, 1, N)
    Cm: jax.Array,  # (B, 1, N)
    state: jax.Array,  # (B, H, P, N) fp32
):
    f32 = jnp.float32
    dA = jnp.exp(dt[:, 0].astype(f32) * A.astype(f32))  # (B, H)
    xdt = x[:, 0].astype(f32) * dt[:, 0].astype(f32)[..., None]  # (B, H, P)
    new_state = state * dA[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, Bm[:, 0].astype(f32)
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(f32), new_state)
    return y[:, None], new_state  # (B, 1, H, P)


def _causal_conv_full(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, L, C) with taps (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b


def mamba_block(
    p: dict,
    x: jax.Array,  # (B, L, d)
    ctx: Ctx,
    cache: dict | None = None,  # {"conv": (B, K-1, conv_dim), "state": (B,H,P,N)}
):
    """Returns (out (B, L, d), new_cache)."""
    cfg, sh = ctx.cfg, ctx.shard
    B, L, _ = x.shape
    din, H, N, P = cfg.d_inner, cfg.mamba_heads, cfg.ssm_state, cfg.mamba_head_dim
    K = cfg.conv_kernel

    zxbcdt = linear(p["in_proj"], x, ctx)
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din : 2 * din + 2 * N]
    dt = jax.nn.softplus(
        zxbcdt[..., 2 * din + 2 * N :].astype(jnp.float32) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = None
    if cache is None:
        xBC = _causal_conv_full(xBC, p["conv_w"], p["conv_b"])
    else:
        window = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
        new_conv = window[:, -(K - 1) :, :]
        xBC = _causal_conv_full(window, p["conv_w"], p["conv_b"])[:, -L:, :]
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :din].reshape(B, L, H, P)
    Bm = xBC[..., din : din + N]
    Cm = xBC[..., din + N :]

    chunk = ctx.ex.ssd_chunk or _pick_chunk(L)
    if cache is None:
        compute_dtype = jnp.bfloat16 if ctx.ex.ssd_bf16 else jnp.float32
        y, _ = ssd_chunked(
            xs, dt, A, Bm, Cm, chunk=min(chunk, L), compute_dtype=compute_dtype
        )
    elif L == 1:  # decode: O(1) recurrent update
        y, new_state = ssd_decode_step(xs, dt, A, Bm, Cm, cache["state"])
        new_cache = {"conv": new_conv, "state": new_state}
    else:  # prefill continuing from cached state
        y, new_state = ssd_chunked(
            xs, dt, A, Bm, Cm, chunk=min(chunk, L), init_state=cache["state"]
        )
        new_cache = {"conv": new_conv, "state": new_state}

    y = y.reshape(B, L, din) + xBC[..., :din] * p["D"].repeat(P)[None, None, :]
    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), -1, keepdims=True)
    g = (g * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_scale"]).astype(x.dtype)
    out = linear(p["out_proj"], g, ctx)
    return sh.constrain(out, "batch", None, None), new_cache


def _pick_chunk(L: int) -> int:
    for c in (64, 32, 16, 8, 4, 2, 1):
        if L % c == 0:
            return c
    return 1
