"""Mixture-of-experts FFN: top-k routing + MegaBlocks-style grouped GEMM.

Distribution (DESIGN.md §4): dispatch is *local to each data shard* via
``jax.shard_map`` — routing, sort and ``lax.ragged_dot`` never cross the data
axis; expert weights are TP-sharded on d_ff over the model axis (expert-TP,
not EP, so arbitrary expert counts never constrain the mesh) and the second
ragged_dot's partial sums reduce with one psum over "model" — the same
collective a dense TP MLP pays.  Measured on the fake-device mesh: the naive
GSPMD formulation instead all-gathers the full (T*k, d) dispatch per layer.

Qwen2-MoE-style shared experts run as a dense SwiGLU branch added to the
routed output, and the router uses the standard load-balancing auxiliary
loss (Switch §2.2), returned alongside the output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, mlp, mlp_specs
from repro.models.params import PSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    s = {
        "router": PSpec((d, E), ("embed", None), dtype=jnp.float32),
        "w_gate": PSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_up": PSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_down": PSpec((E, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        s["shared"] = mlp_specs(cfg, d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
        s["shared_gate"] = PSpec((d, 1), ("embed", None), dtype=jnp.float32)
    return s


def _route(x: jax.Array, router_w: jax.Array, cfg: ModelConfig):
    """(T, d) -> combine weights (T, k), expert ids (T, k), aux loss scalar."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    weights = weights / jnp.sum(weights, -1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    E = cfg.num_experts
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e)
    return weights.astype(x.dtype), idx, aux


def _moe_local(x, router_w, w_gate, w_up, w_down, *, cfg: ModelConfig, psum_axes):
    """Per-shard expert compute. x: (T_local, d); weights may be TP slices."""
    k = cfg.num_experts_per_tok
    weights, idx, aux = _route(x, router_w, cfg)
    flat = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat)
    token_of = order // k
    xs = jnp.take(x, token_of, axis=0)  # (T*k, d) sorted by expert
    group_sizes = jnp.bincount(flat, length=cfg.num_experts)
    g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
    u = jax.lax.ragged_dot(xs, w_up, group_sizes)
    h = jax.nn.silu(g) * u  # (T*k, f_local)
    y = jax.lax.ragged_dot(h, w_down, group_sizes)  # partial over f_local
    if psum_axes:
        y = jax.lax.psum(y, psum_axes)
        aux = jax.lax.pmean(aux, psum_axes)
    combine = weights.reshape(-1)[order][:, None].astype(y.dtype)
    out = jnp.zeros_like(x).at[token_of].add(y * combine)
    return out, aux


def moe_ffn(p: dict, x: jax.Array, ctx: Ctx):
    """(B, S, d) -> (B, S, d), aux_loss. shard_map'd when a mesh is active."""
    from repro.core.convert import LUTLinear

    if isinstance(p.get("w_gate"), LUTLinear):
        raise NotImplementedError(
            "convert_params(convert_experts=True) builds expert LUT tables "
            "for size/op accounting, but moe_ffn has no LUT execution path "
            "yet (ragged_dot needs the raw expert weights) — serve MoE "
            "models with experts left dense (the default)"
        )
    cfg, sh = ctx.cfg, ctx.shard
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    if sh.mesh is None:
        out, aux = _moe_local(
            xt, p["router"], p["w_gate"], p["w_up"], p["w_down"], cfg=cfg, psum_axes=()
        )
    else:
        dp = sh.data_axes  # e.g. ("pod", "data")
        tp = sh.model_axes  # ("model",)
        # shard_map blocks must divide evenly; tiny decode batches (e.g.
        # long_500k's B=1) replicate over data and compute redundantly
        if (B * S) % max(sh.axis_size(*dp), 1) != 0:
            dp = ()
        tok_spec = P(dp, None) if dp else P(None, None)
        fn = functools.partial(_moe_local, cfg=cfg, psum_axes=tp)
        out, aux = shard_map(
            fn,
            mesh=sh.mesh,
            in_specs=(
                tok_spec,
                P(None, None),
                P(None, None, tp[0] if tp else None),
                P(None, None, tp[0] if tp else None),
                P(None, tp[0] if tp else None, None),
            ),
            out_specs=(tok_spec, P()),
            check_vma=False,
        )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = out.reshape(B, S, d)
    if "shared" in p:
        gate = jax.nn.sigmoid(x.astype(jnp.float32) @ p["shared_gate"]).astype(x.dtype)
        out = out + gate * mlp(p["shared"], x, ctx)
    return sh.constrain(out, "batch", None, None), aux
