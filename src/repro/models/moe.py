"""Mixture-of-experts FFN: top-k routing + grouped expert execution.

Distribution (DESIGN.md §4): dispatch is *local to each data shard* via
``jax.shard_map`` — routing, sort and the grouped expert evaluation never
cross the data axis; expert weights are TP-sharded on d_ff over the model
axis (expert-TP, not EP, so arbitrary expert counts never constrain the
mesh) and the down-projection's partial sums reduce with one psum over
"model" — the same collective a dense TP MLP pays.  Measured on the
fake-device mesh: the naive GSPMD formulation instead all-gathers the full
(T*k, d) dispatch per layer.  The router's load-balance aux loss is
pmean'd over the data AND model axes inside the same shard_map, so the
returned scalar is the global batch mean and genuinely replicated (the
``P()`` out-spec is sound).

Expert execution dispatches per projection on the parameter leaf:

* raw ``(E, q, p)`` arrays     -> ``lax.ragged_dot`` (dense grouped GEMM)
* ``core.convert.LUTLinear``   -> the ragged LUT path (TableNet)
* ``core.convert.LUTGroup``    -> same, both gate/up in one dispatch

so ``convert_params(convert_experts=True)`` trees serve multiplier-free:
the input decomposition of each token is expert-independent, so LUT codes
are packed ONCE per token (then gathered into the expert-sorted order) and
``kernels.lut_affine.lut_affine_experts`` — or its jnp oracle under GSPMD
— replaces the ragged_dot calls entirely.  Mixed trees (a plan converting
only some of gate/up/down) execute coherently, each projection on its own
path.  TP sharding of LUT experts: gate/up tables shard their output dim
(= d_ff) over "model" exactly like the dense weights; the down tables
shard their CHUNK axis (the d_ff contraction lives in the chunks), each
shard packs its local h slice under a chunk-aligned local plan, and the
same psum reduces the partial sums — when d_ff doesn't split into
whole chunks per shard, expert TP is dropped (replicated tables,
redundant compute) rather than served wrong.

Qwen2-MoE-style shared experts run as a dense SwiGLU branch added to the
routed output, and the router uses the standard load-balancing auxiliary
loss (Switch §2.2), returned alongside the output.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.core.convert import LUTGroup, LUTLinear
from repro.core.lut import LUTPlan, pack_codes, plane_scales
from repro.core.lut_tl1 import TL1Plan, build_act_lut, quantize_acts, unpack_indices
from repro.kernels.common import check_acc_contract
from repro.models.layers import Ctx, ExecCfg, mlp, mlp_specs
from repro.models.params import PSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    s = {
        "router": PSpec((d, E), ("embed", None), dtype=jnp.float32),
        "w_gate": PSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_up": PSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_down": PSpec((E, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        s["shared"] = mlp_specs(cfg, d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
        s["shared_gate"] = PSpec((d, 1), ("embed", None), dtype=jnp.float32)
    return s


def _route(x: jax.Array, router_w: jax.Array, cfg: ModelConfig):
    """(T, d) -> combine weights (T, k), expert ids (T, k), aux loss scalar."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    weights = weights / jnp.sum(weights, -1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    E = cfg.num_experts
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e)
    return weights.astype(x.dtype), idx, aux


# ---------------------------------------------------------------------------
# Per-projection expert dispatch (dense ragged_dot | ragged LUT)
# ---------------------------------------------------------------------------


def _member_node(experts: dict, name: str):
    """Resolve a projection by name, whether stored per-name or inside a
    pre-stacked expert :class:`LUTGroup` (``"w_gate+w_up"`` keys)."""
    if name in experts:
        return experts[name]
    for node in experts.values():
        if isinstance(node, LUTGroup) and name in node.members:
            return node
    raise KeyError(name)


def _local_plan(plan, tables: jax.Array):
    """The packing plan for a possibly chunk-axis-TP-sliced table leaf: a
    shard holding ``k_local`` of the ``k`` chunks packs a ``k_local * m``
    feature slice (exact: LUT affine is linear in the table chunks, and the
    slicing is only enabled when chunk boundaries align with the shards).

    TL1 leaves never chunk-shard (``_down_chunks_shardable`` forces the TP
    drop), and their packed-chunk axis sits at ``-2``, not ``-3`` — so the
    plan passes through untouched."""
    if plan.table_family == "tl1":
        return plan
    k_local = tables.shape[-3]
    if k_local == plan.num_chunks:
        return plan
    return dataclasses.replace(plan, in_features=k_local * plan.chunk_size)


def _ragged_lut(
    tables: jax.Array,  # (E, G, k, entries, p)
    plan: LUTPlan,
    codes: jax.Array,  # (T, n, k) expert-sorted
    group_sizes: jax.Array,  # (E,)
    ex: ExecCfg,
    scale: jax.Array | None = None,  # narrow-table dequant scale
) -> jax.Array:
    """(G, T, p) float32 — every token row against ITS expert's tables."""
    check_acc_contract("lut_affine_experts", plan, "float32")
    scales = jnp.asarray(plane_scales(plan), jnp.float32)
    if scale is not None:  # power-of-2 dequant folds into the plane scales
        scales = scales * scale
    shift = plan.index_bits if plan.mode == "bitplane_shift" else 0
    if ex.use_pallas:
        from repro.kernels.lut_affine.ops import lut_affine_experts

        return lut_affine_experts(
            codes,
            tables,
            scales,
            group_sizes,
            blocks=plan.blocks,
            shift_bits=shift,
            plan=plan,
        )
    from repro.kernels.lut_affine.ref import lut_affine_experts_ref

    return lut_affine_experts_ref(
        codes, tables, scales, group_sizes, shift_bits=shift
    )


def _ragged_tl1(
    tables: jax.Array,  # (E, G, kb, p) uint8 packed base-3 indices
    plan: TL1Plan,
    acts: jax.Array,  # (T, 4*kb) expert-sorted activation codes
    group_sizes: jax.Array,  # (E,)
    scale: jax.Array | None = None,  # (E, G) per-expert ternary scales
    act_scale: jax.Array | None = None,  # (T, 1) expert-sorted, int8 mode only
) -> jax.Array:
    """(G, T, p) float32 — TL1 twin of :func:`_ragged_lut`.

    The activation LUT is per TOKEN (the inverse of the weight family, where
    tables are per expert and codes per token), so the ragged structure only
    selects which expert's packed index matrix each sorted row gathers from.
    Runs as a jnp oracle on every path — the transient ``(T, 2kb, 9)`` LUT is
    small and the gather is the whole computation, so there is no separate
    experts Pallas kernel for this family."""
    check_acc_contract(
        "ragged_tl1", plan, "int32" if plan.act_bits is not None else "float32"
    )
    E, G = tables.shape[0], tables.shape[1]
    T = acts.shape[0]
    expert_of = jnp.repeat(jnp.arange(E), group_sizes, total_repeat_length=T)
    idx = unpack_indices(tables)  # (E, G, 2kb, p)
    rows = jnp.take(idx, expert_of, axis=0)  # (T, G, 2kb, p)
    lut = build_act_lut(acts)[:, None]  # (T, 1, 2kb, 9)
    lut = jnp.broadcast_to(lut, rows.shape[:-1] + (lut.shape[-1],))
    g = jnp.take_along_axis(lut, rows, axis=-1)  # (T, G, 2kb, p)
    acc = jnp.int32 if jnp.issubdtype(g.dtype, jnp.integer) else jnp.float32
    out = jnp.moveaxis(jnp.sum(g.astype(acc), axis=-2), 0, 1)  # (G, T, p)
    out = out.astype(jnp.float32)
    if act_scale is not None:
        out = out * act_scale[None]  # (1, T, 1)
    if scale is not None:
        out = out * jnp.moveaxis(scale[expert_of], 0, 1)[..., None]  # (G, T, 1)
    return out


def _moe_local(
    x, experts: dict, *, cfg: ModelConfig, ex: ExecCfg, psum_axes, mean_axes
):
    """Per-shard expert compute. x: (T_local, d); tables/weights may be TP
    slices.  Dispatches per projection on the leaf type, so dense, fully
    converted, and mixed expert trees all execute coherently."""
    k = cfg.num_experts_per_tok
    weights, idx, aux = _route(x, experts["router"], cfg)
    flat = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat)
    token_of = order // k
    group_sizes = jnp.bincount(flat, length=cfg.num_experts)

    # LUT input decomposition is expert-independent: pack x ONCE per token,
    # then gather the packed codes into the expert-sorted (T*k) order — the
    # same gather the dense path applies to the raw activations.
    pack_cache: dict = {}  # keyed by plan; TL1 entries hold (codes, act_scale)

    def sorted_codes(plan: LUTPlan, src: jax.Array, gather: bool) -> jax.Array:
        if gather:  # src is (T, d): pack per token, gather to (T*k, n, kc)
            if plan not in pack_cache:
                pack_cache[plan] = pack_codes(src, plan)
            return jnp.take(pack_cache[plan], token_of, axis=0)
        return pack_codes(src, plan)  # src already expert-sorted (h)

    def sorted_tl1(plan: TL1Plan, src: jax.Array, gather: bool):
        """(codes, act_scale) in expert-sorted row order — quantization is
        expert-independent, so it runs once per token like the packing."""
        if gather:
            if plan not in pack_cache:
                pack_cache[plan] = quantize_acts(src, plan)
            codes, ascale = pack_cache[plan]
            codes = jnp.take(codes, token_of, axis=0)
            if ascale is not None:
                ascale = jnp.take(ascale, token_of, axis=0)
            return codes, ascale
        return quantize_acts(src, plan)

    def project_tl1(node, name: str, src: jax.Array, gather: bool) -> jax.Array:
        plan = node.plan
        codes, ascale = sorted_tl1(plan, src, gather)
        if isinstance(node, LUTGroup):
            g = node.members.index(name)
            tables, scale = node.tables[:, g : g + 1], node.scale[:, g : g + 1]
        else:
            tables, scale = node.tables[:, None], node.scale[:, None]
        y = _ragged_tl1(
            tables, plan, codes, group_sizes, scale=scale, act_scale=ascale
        )
        return y[0].astype(x.dtype)

    def project(name: str, src: jax.Array, gather: bool) -> jax.Array:
        """One expert projection over the expert-sorted rows."""
        node = _member_node(experts, name)
        if isinstance(node, (LUTGroup, LUTLinear)) and isinstance(
            node.plan, TL1Plan
        ):
            return project_tl1(node, name, src, gather)
        if isinstance(node, LUTGroup):
            g = node.members.index(name)
            plan = _local_plan(node.plan, node.tables)
            codes = sorted_codes(plan, src, gather)
            y = _ragged_lut(
                node.tables[:, g : g + 1],
                plan,
                codes,
                group_sizes,
                ex,
                scale=node.scale,
            )
            return y[0].astype(x.dtype)
        if isinstance(node, LUTLinear):
            plan = _local_plan(node.plan, node.tables)
            codes = sorted_codes(plan, src, gather)
            y = _ragged_lut(
                node.tables[:, None], plan, codes, group_sizes, ex, scale=node.scale
            )[0]
            return y.astype(x.dtype)
        rows = jnp.take(src, token_of, axis=0) if gather else src
        return jax.lax.ragged_dot(rows, node, group_sizes)

    gate_node = _member_node(experts, "w_gate")
    up_node = _member_node(experts, "w_up")
    if isinstance(gate_node, LUTGroup) and gate_node is up_node:
        # pre-stacked gate/up pair: ONE fused ragged dispatch for both
        plan = _local_plan(gate_node.plan, gate_node.tables)
        if isinstance(plan, TL1Plan):
            codes, ascale = sorted_tl1(plan, x, gather=True)
            gu = _ragged_tl1(
                gate_node.tables,
                plan,
                codes,
                group_sizes,
                scale=gate_node.scale,
                act_scale=ascale,
            )
        else:
            codes = sorted_codes(plan, x, gather=True)
            gu = _ragged_lut(
                gate_node.tables, plan, codes, group_sizes, ex, scale=gate_node.scale
            )
        order_g = {m: i for i, m in enumerate(gate_node.members)}
        g = gu[order_g["w_gate"]].astype(x.dtype)
        u = gu[order_g["w_up"]].astype(x.dtype)
    else:
        g = project("w_gate", x, gather=True)
        u = project("w_up", x, gather=True)
    h = jax.nn.silu(g) * u  # (T*k, f_local)
    y = project("w_down", h, gather=False)  # partial over f_local
    if psum_axes:
        y = jax.lax.psum(y, psum_axes)
    if mean_axes:
        aux = jax.lax.pmean(aux, mean_axes)
    combine = weights.reshape(-1)[order][:, None].astype(y.dtype)
    out = jnp.zeros_like(x).at[token_of].add(y * combine)
    return out, aux


# ---------------------------------------------------------------------------
# TP sharding of the expert parameter tree
# ---------------------------------------------------------------------------


def _down_chunks_shardable(plan, tp_size: int) -> bool:
    """Chunk-axis TP slices are exact only when every shard holds whole
    chunks covering exactly its d_ff slice (no ragged tail chunk).  TL1
    packed bytes interleave two chunks per byte and the activation LUT is
    per token, so TL1 down projections never chunk-shard — expert TP drops
    to replicated tables instead."""
    if plan.table_family == "tl1":
        return False
    return tp_size > 1 and plan.in_features % (tp_size * plan.chunk_size) == 0


def _lut_node_spec(node, tables_spec: P):
    """Node-shaped in_spec for a LUT leaf bundle: the table leaf gets
    ``tables_spec``; the scalar dequant scale (present only for narrow
    table formats) is replicated; expert biases are never emitted by
    conversion, so ``b`` stays the empty subtree."""
    return dataclasses.replace(
        node, tables=tables_spec, b=None, scale=None if node.scale is None else P()
    )


def _expert_specs(experts: dict, tp: tuple) -> dict:
    """shard_map in_specs for the expert tree: one spec subtree per node.
    Gate/up shard their output (d_ff) dim — the table p axis — over the
    model axis exactly like the dense weights; the down projection shards
    its contraction: the weight's d_ff dim when dense, the table chunk axis
    when converted."""
    tpa = tp[0] if tp else None
    specs: dict = {}
    for key, node in experts.items():
        if key == "router":
            specs[key] = P(None, None)
        elif isinstance(node, (LUTGroup, LUTLinear)):
            # ndim-generic over both families: weight tables are
            # (E, [G,] k, entries, p), TL1 packed leaves (E, [G,] kb, p).
            axes = [None] * node.tables.ndim
            if key == "w_down" and node.plan.table_family == "weight":
                axes[-3] = tpa  # (..., k, entries, d): shard chunks (= d_ff)
            else:  # gate/up shard the output dim (p = f); TL1 down never
                axes[-1] = tpa  # TP-shards (tp was already dropped above)
            specs[key] = _lut_node_spec(node, P(*axes))
        elif key == "w_down":  # (E, f, d)
            specs[key] = P(None, tpa, None)
        else:  # raw (E, d, f) gate/up
            specs[key] = P(None, None, tpa)
    return specs


def moe_ffn(p: dict, x: jax.Array, ctx: Ctx):
    """(B, S, d) -> (B, S, d), aux_loss. shard_map'd when a mesh is active."""
    cfg, sh = ctx.cfg, ctx.shard
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    experts = {k: v for k, v in p.items() if k not in ("shared", "shared_gate")}
    if sh.mesh is None:
        out, aux = _moe_local(
            xt, experts, cfg=cfg, ex=ctx.ex, psum_axes=(), mean_axes=()
        )
    else:
        dp = sh.data_axes  # e.g. ("pod", "data")
        tp = sh.model_axes  # ("model",)
        down = _member_node(experts, "w_down")
        if isinstance(down, (LUTLinear, LUTGroup)) and not _down_chunks_shardable(
            down.plan, sh.axis_size(*tp) if tp else 0
        ):
            # chunk boundaries don't align with the shards: replicate the
            # expert tables (redundant compute) rather than serve wrong
            tp = ()
        # shard_map blocks must divide evenly; tiny decode batches (e.g.
        # long_500k's B=1) replicate over data and compute redundantly
        if (B * S) % max(sh.axis_size(*dp), 1) != 0:
            dp = ()
        tok_spec = P(dp, None) if dp else P(None, None)
        fn = functools.partial(
            _moe_local,
            cfg=cfg,
            ex=ctx.ex,
            psum_axes=tp,
            mean_axes=tuple(dp) + tuple(tp),
        )
        out, aux = shard_map(
            fn,
            mesh=sh.mesh,
            in_specs=(tok_spec, _expert_specs(experts, tp)),
            out_specs=(tok_spec, P()),
            check_vma=False,
        )(xt, experts)
    out = out.reshape(B, S, d)
    if "shared" in p:
        gate = jax.nn.sigmoid(x.astype(jnp.float32) @ p["shared_gate"]).astype(x.dtype)
        out = out + gate * mlp(p["shared"], x, ctx)
    return sh.constrain(out, "batch", None, None), aux
