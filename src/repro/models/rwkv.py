"""RWKV-6 ("Finch") blocks: attention-free, data-dependent per-channel decay.

Training/prefill runs the chunked parallel form of the WKV linear recurrence
(GLA-style: intra-chunk quadratic term with cumulative log-decay weights +
inter-chunk state carry); decode is the O(1) recurrent update.  A naive
recurrent reference lives in ``tests/test_rwkv.py``.

Simplifications vs the full Finch block, noted in DESIGN.md §5: static
per-channel token-shift mixing coefficients (the decay — the paper's
headline feature — keeps its data-dependent LoRA form); no per-head extra
LoRA on u.  The WKV recurrence has data-dependent transition weights and is
therefore not LUT-convertible; the r/k/v/g/o projections are.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, linear, linear_spec
from repro.models.params import PSpec


def rwkv_specs(cfg: ModelConfig) -> dict:
    d, H, hd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    r = cfg.decay_lora_rank
    def mix():
        return PSpec((d,), (None,), init="zeros")

    return {
        "time": {
            "mu_r": mix(),
            "mu_k": mix(),
            "mu_v": mix(),
            "mu_w": mix(),
            "mu_g": mix(),
            "w_r": linear_spec(d, d),
            "w_k": linear_spec(d, d),
            "w_v": linear_spec(d, d),
            "w_g": linear_spec(d, d),
            "w_o": linear_spec(d, d, axes=("heads_flat", "embed")),
            "decay_base": PSpec((d,), (None,), init="zeros"),
            "decay_A": PSpec((d, r), ("embed", None), scale=0.01),
            "decay_B": PSpec((r, d), (None, "heads_flat"), scale=0.01),
            "u": PSpec((H, hd), ("heads", None), init="zeros"),
            "ln_scale": PSpec((d,), (None,), init="ones"),
            "ln_bias": PSpec((d,), (None,), init="zeros"),
        },
        "channel": {
            "mu_k": mix(),
            "mu_r": mix(),
            "w_k": linear_spec(d, cfg.d_ff, axes=("embed", "mlp")),
            "w_v": linear_spec(cfg.d_ff, d, axes=("mlp", "embed")),
            "w_r": linear_spec(d, d),
        },
    }


def _token_shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """(B, L, d) -> previous token's features (zeros / cache for t=0)."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv_chunked(
    r: jax.Array,  # (B, L, H, K)
    k: jax.Array,  # (B, L, H, K)
    v: jax.Array,  # (B, L, H, V)
    logw: jax.Array,  # (B, L, H, K)  log decay, < 0
    u: jax.Array,  # (H, K) bonus for the current token
    chunk: int = 32,
    init_state: jax.Array | None = None,  # (B, H, K, V) fp32
    unroll: bool = False,  # analysis probes: HLO cost counts loop bodies once
):
    """y_t = r_t @ (S_t + diag(u) k_t v_t^T);  S_{t+1} = diag(w_t) S_t + k_t v_t^T
    (with S_t the state *before* absorbing token t). fp32 inside."""
    B, L, H, K = r.shape
    V = v.shape[-1]
    assert L % chunk == 0
    nc = L // chunk
    f32 = jnp.float32
    rc = jnp.moveaxis(r.astype(f32).reshape(B, nc, chunk, H, K), 1, 0)
    kc = jnp.moveaxis(k.astype(f32).reshape(B, nc, chunk, H, K), 1, 0)
    vc = jnp.moveaxis(v.astype(f32).reshape(B, nc, chunk, H, V), 1, 0)
    lw = jnp.moveaxis(logw.astype(f32).reshape(B, nc, chunk, H, K), 1, 0)

    i_idx = jnp.arange(chunk)
    tri = (i_idx[:, None] > i_idx[None, :]).astype(f32)  # strict lower
    s0 = jnp.zeros((B, H, K, V), f32) if init_state is None else init_state.astype(f32)

    def chunk_step(s, inp):
        rch, kch, vch, lwch = inp  # (B, c, H, {K, K, V, K})
        cum = jnp.cumsum(lwch, axis=1)  # (B, c, H, K) sum_{t<=i}
        cum_in = cum - lwch  # sum_{t<i}
        # intra-chunk: att[i,j] = sum_k r_ik k_jk exp(cum_in_i - cum_j), j < i.
        # Exponents are formed as differences BEFORE exp (always <= 0 on the
        # masked triangle) — exact and overflow-free, unlike the factored
        # exp(cum_in_i)*exp(-cum_j) form which overflows under strong decay.
        expo = cum_in[:, :, None] - cum[:, None, :]  # (B, c, c, H, K)
        w_ij = jnp.exp(jnp.minimum(expo, 0.0)) * tri[None, :, :, None, None]
        att = jnp.einsum("bihk,bjhk,bijhk->bhij", rch, kch, w_ij)
        bonus = jnp.einsum("bihk,hk,bihk->bhi", rch, u.astype(f32), kch)
        y = jnp.einsum("bhij,bjhv->bihv", att, vch)
        y = y + bonus.transpose(0, 2, 1)[..., None] * vch
        # inter-chunk: contribution of the state entering this chunk
        y = y + jnp.einsum("bihk,bhkv->bihv", rch * jnp.exp(cum_in), s)
        # carry: S_end = diag(prod w) S_start + sum_j diag(prod_{t>j} w) k_j v_j
        decay_rest = jnp.exp(cum[:, -1:] - cum)  # (B, c, H, K), <= 1
        new_s = s * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
            "bjhk,bjhv->bhkv", kch * decay_rest, vch
        )
        return new_s, y

    final, ys = jax.lax.scan(
        chunk_step, s0, (rc, kc, vc, lw), unroll=True if unroll else 1
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, H, V)
    return y, final


def wkv_decode_step(r, k, v, logw, u, state):
    """Single token: r/k/v/logw (B, 1, H, K|V); state (B, H, K, V) fp32."""
    f32 = jnp.float32
    r1, k1, v1, w1 = (t[:, 0].astype(f32) for t in (r, k, v, logw))
    kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
    y = jnp.einsum("bhk,bhkv->bhv", r1, state + u.astype(f32)[None, :, :, None] * kv)
    new_state = state * jnp.exp(w1)[..., None] + kv
    return y[:, None], new_state


def _group_norm(x: jax.Array, H: int, scale, bias, eps) -> jax.Array:
    """Per-head layernorm over the head dim of (B, L, d=H*hd)."""
    B, L, d = x.shape
    xh = x.astype(jnp.float32).reshape(B, L, H, d // H)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(B, L, d)
    return y * scale + bias


def rwkv_time_mix(
    p: dict,
    x: jax.Array,
    ctx: Ctx,
    last: jax.Array | None,
    wkv_state: jax.Array | None,
):
    """Returns (out, new_last, new_wkv_state)."""
    cfg = ctx.cfg
    B, L, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    xx = _token_shift(x, last)

    def mixed(mu):
        return x + (xx - x) * mu[None, None, :]

    r = linear(p["w_r"], mixed(p["mu_r"]), ctx).reshape(B, L, H, hd)
    k = linear(p["w_k"], mixed(p["mu_k"]), ctx).reshape(B, L, H, hd)
    v = linear(p["w_v"], mixed(p["mu_v"]), ctx).reshape(B, L, H, hd)
    g = linear(p["w_g"], mixed(p["mu_g"]), ctx)
    # Finch data-dependent decay: w = exp(-exp(base + LoRA(x_w)))
    dlora = (mixed(p["mu_w"]) @ p["decay_A"]) @ p["decay_B"]
    logw = -jnp.exp(
        jnp.clip(p["decay_base"][None, None, :] + dlora.astype(jnp.float32), -8.0, 4.0)
    ).reshape(B, L, H, hd)

    if wkv_state is None:
        y, new_state = wkv_chunked(
            r, k, v, logw, p["u"], chunk=_pick_chunk(L), unroll=ctx.ex.inner_unroll
        )
    elif L == 1:  # decode: O(1) recurrent update
        y, new_state = wkv_decode_step(r, k, v, logw, p["u"], wkv_state)
    else:  # prefill continuing from cached state
        y, new_state = wkv_chunked(
            r, k, v, logw, p["u"], chunk=_pick_chunk(L), init_state=wkv_state
        )
    y = y.reshape(B, L, d).astype(x.dtype)
    y = _group_norm(y, H, p["ln_scale"], p["ln_bias"], cfg.norm_eps).astype(x.dtype)
    out = linear(p["w_o"], y * jax.nn.silu(g), ctx)
    return ctx.shard.constrain(out, "batch", None, None), x[:, -1], new_state


def rwkv_channel_mix(p: dict, x: jax.Array, ctx: Ctx, last: jax.Array | None):
    xx = _token_shift(x, last)
    xk = x + (xx - x) * p["mu_k"][None, None, :]
    xr = x + (xx - x) * p["mu_r"][None, None, :]
    h = jnp.square(jax.nn.relu(linear(p["w_k"], xk, ctx)))
    h = ctx.shard.constrain(h, "batch", None, "mlp")
    out = jax.nn.sigmoid(linear(p["w_r"], xr, ctx)) * linear(p["w_v"], h, ctx)
    return ctx.shard.constrain(out, "batch", None, None), x[:, -1]


def _pick_chunk(L: int) -> int:
    for c in (32, 16, 8, 4, 2, 1):
        if L % c == 0:
            return c
    return 1


# ---------------------------------------------------------------------------
# RWKV LM (model-level assembly)
# ---------------------------------------------------------------------------


def rwkv_lm_specs(cfg: ModelConfig) -> dict:
    from repro.models import layers as L
    from repro.models.transformer import stack_specs

    d = cfg.d_model
    block = {
        "ln1": L.norm_spec(cfg),
        "time": rwkv_specs(cfg)["time"],
        "ln2": L.norm_spec(cfg),
        "channel": rwkv_specs(cfg)["channel"],
    }
    return {
        "embed": PSpec((cfg.padded_vocab, d), ("vocab", "embed"), init="embed"),
        "ln0": L.norm_spec(cfg),  # rwkv: extra norm after embedding
        "blocks": stack_specs(block, cfg.num_layers),
        "ln_f": L.norm_spec(cfg),
        "lm_head": L.linear_spec(d, cfg.padded_vocab, axes=("embed", "vocab")),
    }


def forward(params, tokens, ctx: Ctx, positions=None, cache=None, embeds=None):
    """Returns (logits, new_cache, aux). cache: {"layers": {shift_a, shift_c,
    wkv}, "index"} — O(1) state, no pos/valid ring."""
    from repro.models import layers as L
    from repro.models.transformer import _remat_policy, embed_tokens, lm_logits

    cfg = ctx.cfg
    x = embed_tokens(params, tokens, ctx)
    x = L.apply_norm(params["ln0"], x, cfg)

    cache_layers = cache["layers"] if cache is not None else None

    def body(carry, xs):
        lp, lc = xs
        la = lc.get("shift_a") if lc else None
        lw = lc.get("wkv") if lc else None
        h, new_a, new_w = rwkv_time_mix(
            lp["time"], L.apply_norm(lp["ln1"], carry, cfg), ctx, la, lw
        )
        x2 = carry + h
        lc_ = lc.get("shift_c") if lc else None
        h, new_c = rwkv_channel_mix(
            lp["channel"], L.apply_norm(lp["ln2"], x2, cfg), ctx, lc_
        )
        x2 = x2 + h
        out_c = {"shift_a": new_a, "shift_c": new_c, "wkv": new_w} if lc else {}
        return x2, out_c

    if ctx.ex.remat != "none":
        body = jax.checkpoint(body, policy=_remat_policy(ctx.ex.remat))
    xs = (params["blocks"], cache_layers if cache_layers is not None else {})
    x, new_layers = jax.lax.scan(body, x, xs, unroll=True if ctx.ex.inner_unroll else 1)
    x = L.apply_norm(params["ln_f"], x, cfg)
    if ctx.ex.logits == "last":
        x = x[:, -1:]
    logits = lm_logits(params, x, ctx)
    new_cache = None
    if cache is not None:
        new_cache = dict(
            cache, layers=new_layers, index=cache["index"] + tokens.shape[1]
        )
    return logits, new_cache, jnp.zeros((), jnp.float32)
