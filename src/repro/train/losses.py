"""Losses: next-token cross entropy with padded-vocab + label masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(
    logits: jax.Array,  # (B, S, V_padded)
    labels: jax.Array,  # (B, S) int32; -1 = ignore
    vocab_size: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (mean loss over valid tokens, valid-token count)."""
    Vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if Vp > vocab_size:  # mask Megatron-style vocab padding columns
        col = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
        lf = jnp.where(col < vocab_size, lf, -1e9)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - picked, 0.0)
    count = jnp.maximum(valid.sum(), 1)
    return nll.sum() / count, count
