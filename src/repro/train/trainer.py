"""Fault-tolerant training loop.

Fault-tolerance model (designed for 1000+ nodes, exercised here on the CPU
fake mesh):
  * checkpoint/restart — atomic sharded checkpoints every N steps
    (``dist.checkpoint``); on any step failure the loop restores the last
    committed step and replays.  Data is deterministic in (seed, step), so
    replayed steps are bit-idempotent.
  * preemption — a SIGTERM/flag-file request triggers a checkpoint + clean
    exit at the next step boundary.
  * elastic scaling — restore reshards onto whatever mesh the restarted job
    has (checkpoints store full logical arrays).
  * stragglers — steps are timed; the mitigation at scale is deterministic
    step replay on respawned workers (same (seed, step) => same batch) plus
    the synchronous collectives' built-in barrier; the trainer logs p50/p99
    step times so stragglers are visible.
  * gradient compression — optional int8+error-feedback all-reduce across
    the "pod" axis (the slow DCI hop); see ``dist.compression``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from functools import partial
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.dist import checkpoint as ckpt
from repro.dist.compression import compressed_psum
from repro.models.layers import Ctx
from repro.models.model import model_forward
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedule import warmup_cosine
from repro.train.losses import cross_entropy


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    aux_coef: float = 0.001
    compute_dtype: Any = jnp.bfloat16
    checkpoint_every: int = 100
    keep_last: int = 3
    out_dir: str = "/tmp/repro_run"
    compress_pod_grads: bool = False
    seed: int = 0


def _cast_for_compute(params, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 and a.ndim >= 2 else a,
        params,
    )


def make_loss_fn(ctx: Ctx, tc: TrainConfig):
    cfg = ctx.cfg

    def loss_fn(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _, aux = model_forward(
            _cast_for_compute(params, tc.compute_dtype), inputs, ctx
        )
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:  # VLM: no loss on image tokens
            pad = logits.shape[1] - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full(labels.shape[:1] + (pad,), -1, labels.dtype), labels], 1
            )
        loss, count = cross_entropy(logits, labels, cfg.vocab_size)
        return loss + tc.aux_coef * aux, (loss, aux)

    return loss_fn


def make_grad_fn(ctx: Ctx, tc: TrainConfig):
    """Microbatched (scan-accumulated) gradients; optional pod compression."""
    if tc.compress_pod_grads and ctx.shard.mesh is not None:
        # inside the pod-manual shard_map, "pod" is no longer a GSPMD axis:
        # the inner forward's sharding rules must not mention it
        from repro.dist.sharding import ShardCtx, rules_without_axis

        inner_rules = rules_without_axis(ctx.shard.rules, "pod")
        inner_ctx = dataclasses.replace(
            ctx, shard=ShardCtx(ctx.shard.mesh, inner_rules)
        )
        loss_fn = make_loss_fn(inner_ctx, tc)
    else:
        loss_fn = make_loss_fn(ctx, tc)

    def grads_of(params, batch):
        if tc.microbatches == 1:
            (tot, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return grads, loss, aux

        def micro(carry, mb):
            acc = carry
            (_, (loss, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            acc = jax.tree.map(jnp.add, acc, g)
            return acc, (loss, aux)

        nm = tc.microbatches
        mbs = jax.tree.map(
            lambda a: a.reshape((nm, a.shape[0] // nm) + a.shape[1:]), batch
        )
        zeros = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
        acc, (losses, auxs) = jax.lax.scan(
            micro, zeros, mbs, unroll=True if ctx.ex.inner_unroll else 1
        )
        grads = jax.tree.map(lambda g: g / nm, acc)
        return grads, losses.mean(), auxs.mean()

    if not tc.compress_pod_grads:
        return lambda p, b, err: (*grads_of(p, b), err)

    def compressed(params, batch, err):
        mesh = ctx.shard.mesh
        assert mesh is not None and "pod" in mesh.shape, "pod axis required"

        def per_pod(params, batch, err):
            # mark params pod-VARYING: otherwise the autodiff transpose
            # inserts an implicit (uncompressed!) psum over "pod" for
            # grads of replicated inputs — pvary keeps the partials local
            # so the only cross-pod traffic is the int8 payload below
            params = jax.tree.map(lambda a: pvary(a, "pod"), params)
            g, loss, aux = grads_of(params, batch)
            # error-feedback state has an explicit leading pod dim
            g, new_err = compressed_psum(
                g, jax.tree.map(lambda e: e[0], err), "pod"
            )
            new_err = jax.tree.map(lambda e: e[None], new_err)
            return g, jax.lax.pmean(loss, "pod"), jax.lax.pmean(aux, "pod"), new_err

        b_specs = jax.tree.map(lambda _: P("pod"), batch)
        n_specs = jax.tree.map(lambda _: P(), params)
        e_specs = jax.tree.map(lambda _: P("pod"), err)
        return shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(n_specs, b_specs, e_specs),
            out_specs=(n_specs, P(), P(), e_specs),
            axis_names={"pod"},
        )(params, batch, err)

    return compressed


def make_train_step(ctx: Ctx, tc: TrainConfig) -> Callable:
    grad_fn = make_grad_fn(ctx, tc)

    def train_step(params, opt_state, batch):
        err = opt_state.get("err")
        grads, loss, aux, err = grad_fn(params, batch, err)
        lr = warmup_cosine(
            opt_state["step"],
            peak_lr=tc.peak_lr,
            warmup_steps=tc.warmup_steps,
            total_steps=tc.total_steps,
        )
        params, new_opt, gnorm = adamw_update(grads, opt_state, params, lr, tc.adamw)
        if err is not None:
            new_opt["err"] = err
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm, "lr": lr}
        return params, new_opt, metrics

    return train_step


def init_train_state(ctx: Ctx, tc: TrainConfig, params):
    opt = init_opt_state(params)
    if tc.compress_pod_grads:
        n_pods = ctx.shard.axis_size("pod")
        opt["err"] = jax.tree.map(
            lambda a: jnp.zeros((n_pods,) + a.shape, jnp.float32), params
        )
    return opt


class Trainer:
    """Drives the loop with checkpoint/restart + preemption handling."""

    def __init__(self, ctx: Ctx, tc: TrainConfig, params, data: Iterator[dict],
                 donate: bool = True):
        self.ctx, self.tc = ctx, tc
        self.data = data
        self.step_fn = jax.jit(
            make_train_step(ctx, tc), donate_argnums=(0, 1) if donate else ()
        )
        self.params = params
        self.opt_state = init_train_state(ctx, tc, params)
        self.metrics_log: list[dict] = []
        self._preempted = False
        self.start_step = 0
        self._maybe_restore()

    # -- fault tolerance ------------------------------------------------------
    def _ckpt_dir(self) -> str:
        return os.path.join(self.tc.out_dir, "checkpoints")

    def _maybe_restore(self):
        last = ckpt.latest_step(self._ckpt_dir())
        if last is not None:
            state = {"params": self.params, "opt": self.opt_state}
            state = ckpt.restore_checkpoint(self._ckpt_dir(), last, state)
            self.params, self.opt_state = state["params"], state["opt"]
            self.start_step = last
        return self.start_step

    def _save(self, step: int):
        ckpt.save_checkpoint(
            self._ckpt_dir(), step, {"params": self.params, "opt": self.opt_state},
            keep_last=self.tc.keep_last,
        )

    def request_preemption(self, *_):
        self._preempted = True

    # -- main loop ------------------------------------------------------------
    def run(self, num_steps: Optional[int] = None, max_failures: int = 3) -> list[dict]:
        total = num_steps if num_steps is not None else self.tc.total_steps
        step = self.start_step
        failures = 0
        os.makedirs(self.tc.out_dir, exist_ok=True)
        mfile = open(os.path.join(self.tc.out_dir, "metrics.jsonl"), "a")
        try:
            signal.signal(signal.SIGTERM, self.request_preemption)
        except ValueError:
            pass  # not on the main thread (tests)
        while step < total:
            batch = next(self.data)
            t0 = time.perf_counter()
            try:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch
                )
                loss = float(metrics["loss"])
                if not (loss == loss):  # NaN — treat as failure
                    raise FloatingPointError(f"NaN loss at step {step}")
            except Exception:
                failures += 1
                if failures > max_failures:
                    raise
                # restore-and-replay: deterministic data makes this idempotent
                self.start_step = 0
                restored = self._maybe_restore()
                step = restored
                continue
            dt = time.perf_counter() - t0
            step += 1
            rec = {
                "step": step, "time_s": round(dt, 4),
                **{k: float(v) for k, v in metrics.items()},
            }
            self.metrics_log.append(rec)
            mfile.write(json.dumps(rec) + "\n")
            mfile.flush()
            if step % self.tc.checkpoint_every == 0 or step == total or self._preempted:
                self._save(step)
            if self._preempted:
                break
        mfile.close()
        return self.metrics_log
