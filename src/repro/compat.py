"""Version shims for the jax API surface this repo targets.

The codebase is written against the modern jax names — ``jax.shard_map``
with ``axis_names=`` / ``check_vma=``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)`` and ``jax.lax.pvary`` — but must also
run on the 0.4.x line shipped in the pinned toolchain image, where partial
manual mode is spelled ``jax.experimental.shard_map.shard_map(..., auto=...)``
and the varying-manual-axes type system does not exist.  Import these
wrappers instead of reaching into jax directly.
"""
from __future__ import annotations

import enum

import jax

try:  # jax >= 0.6: typed mesh axes
    from jax.sharding import AxisType
except ImportError:  # 0.4.x: every mesh axis is implicitly Auto (GSPMD)
    AxisType = enum.Enum("AxisType", ["Auto", "Explicit", "Manual"])


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` tolerating ``axis_types`` on old jax.

    This repo only ever uses ``AxisType.Auto``, which is the only (implicit)
    behaviour 0.4.x offers, so dropping the argument is semantics-preserving.
    """
    try:
        return jax.make_mesh(
            axis_shapes,
            axis_names,
            axis_types=axis_types,
            devices=devices,
        )
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """Modern keyword surface for shard_map on either jax line.

    On 0.4.x, ``axis_names`` (the axes the body handles manually) maps to
    the old complementary ``auto`` set; replication checking stays off —
    the 0.4.x checker predates the VMA system the callers are written for.
    0.4.x also lacks an eager impl for partial-auto shard_map, so that case
    is jit-wrapped (a no-op when the caller already traces: jit-of-jit
    inlines).
    """
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        kwargs = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
            **kwargs,
        )
    from jax.experimental.shard_map import shard_map as shard_map_04

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    mapped = shard_map_04(
        f,
        mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
        auto=auto,
    )
    return jax.jit(mapped) if auto else mapped


def pvary(x, axis_name):
    """``jax.lax.pvary`` where it exists; identity on 0.4.x.

    On 0.4.x with ``check_rep=False`` shard_map never inserts the implicit
    transpose-psum that ``pvary`` exists to suppress, so identity is the
    correct degenerate form.
    """
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_name)
    return x
