"""Synthetic data (the container is offline — no dataset downloads).

* LM token streams: a seeded order-1 Markov chain over the vocab with a
  Zipf-ish stationary distribution.  Deterministic in (seed, step, shard):
  a restarted/replayed step regenerates identical batches, which is what
  makes checkpoint-restart and straggler step-replay idempotent.
* MNIST-stand-in images: class-conditional blob patterns + noise, 28x28,
  10 classes — enough structure to reproduce the paper's accuracy-vs-bits
  *trend* (§EXPERIMENTS.md notes this substitution).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int  # per host
    seed: int = 0


def lm_batch(cfg: LMStreamConfig, step: int) -> dict:
    """Deterministic (seed, step) -> {"tokens", "labels"} int32."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
    # zipf-ish marginals; markov structure via mixing with a shifted stream
    k1, k2 = jax.random.split(key)
    ranks = jnp.arange(1, V + 1, dtype=jnp.float32)
    logits = -1.1 * jnp.log(ranks)
    base = jax.random.categorical(k1, logits, shape=(B, S + 1))
    repeat = jax.random.bernoulli(k2, 0.3, (B, S + 1))
    toks = jnp.where(repeat, jnp.roll(base, 1, axis=1), base).astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# MNIST stand-in
# ---------------------------------------------------------------------------


def _class_prototypes(num_classes: int, seed: int) -> np.ndarray:
    """Classes share a stroke pool and differ only in mixing weights — the
    subtle differences make low-bit input quantisation *measurably* hurt,
    which is what lets the paper's Fig. 4/6 saturation trend reproduce."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:28, 0:28]
    pool = []
    for _ in range(12):  # shared strokes
        cy, cx = rng.uniform(4, 24, 2)
        sy, sx = rng.uniform(1.5, 5.0, 2)
        rho = rng.uniform(-0.6, 0.6)
        d = ((yy - cy) / sy) ** 2 + ((xx - cx) / sx) ** 2 - 2 * rho * (
            (yy - cy) / sy
        ) * ((xx - cx) / sx)
        pool.append(np.exp(-d / 2))
    pool = np.stack(pool)
    weights = rng.dirichlet(np.ones(len(pool)) * 0.8, size=num_classes)
    protos = np.einsum("kp,phw->khw", weights.astype(np.float32), pool)
    protos /= protos.max(axis=(1, 2), keepdims=True) + 1e-6
    return protos.astype(np.float32)


_PROTO_CACHE: dict[int, np.ndarray] = {}


def image_batch(batch: int, step: int, seed: int = 0, noise: float = 0.25):
    """-> images (B, 28, 28) in [0,1], labels (B,) — deterministic."""
    if seed not in _PROTO_CACHE:
        _PROTO_CACHE[seed] = _class_prototypes(10, seed + 777)
    protos = _PROTO_CACHE[seed]
    rng = np.random.default_rng(seed * 100_003 + step)
    labels = rng.integers(0, 10, size=batch)
    imgs = protos[labels]
    # random shift +- 2 px and noise
    out = np.zeros_like(imgs)
    for i in range(batch):
        dy, dx = rng.integers(-2, 3, 2)
        out[i] = np.roll(np.roll(imgs[i], dy, 0), dx, 1)
    out = np.clip(out + rng.normal(0, noise, out.shape), 0, 1).astype(np.float32)
    return jnp.asarray(out), jnp.asarray(labels, jnp.int32)
