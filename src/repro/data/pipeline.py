"""Host data pipeline: deterministic sharded batches + background prefetch.

Each host generates only its slice of the global batch (data-parallel
sharding by process index), and a batch is fully determined by
(seed, step) — the properties that make multi-pod input pipelines
restartable and straggler-replayable.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax

from repro.data.synthetic import LMStreamConfig, lm_batch


def host_slice(global_batch: int) -> tuple[int, int]:
    """(host_batch, offset) for this process."""
    n = jax.process_count()
    i = jax.process_index()
    assert global_batch % n == 0, (global_batch, n)
    hb = global_batch // n
    return hb, i * hb


def lm_stream(
    vocab_size: int, seq_len: int, global_batch: int, seed: int = 0, start_step: int = 0
) -> Iterator[dict]:
    hb, off = host_slice(global_batch)
    cfg = LMStreamConfig(vocab_size, seq_len, hb, seed=seed * 1000 + off)
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


def prefetch(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetch (keeps the accelerator fed)."""
    q: queue.Queue = queue.Queue(maxsize=size)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
