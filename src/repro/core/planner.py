"""Partition planner: enumerate LUT configurations and their cost trade-off.

Reproduces the paper's size-vs-operations curves (Figs. 5, 7, 8) and picks a
plan under a memory budget.  All accounting is closed-form from
:class:`repro.core.lut.LUTPlan`; the formulas were validated against every
number the paper states for the linear classifier and the MLP (see
``tests/test_analysis.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

from repro.core.lut import LUTPlan
from repro.core.quantize import FixedPointFormat, Float16Format


@dataclasses.dataclass(frozen=True)
class PlanPoint:
    plan: LUTPlan
    num_tables: int
    lut_bytes: int
    lut_evaluations: int
    shift_add_ops: int

    @staticmethod
    def of(plan: LUTPlan) -> "PlanPoint":
        return PlanPoint(
            plan=plan,
            num_tables=plan.num_chunks,
            lut_bytes=plan.total_lut_bytes,
            lut_evaluations=plan.lut_evaluations,
            shift_add_ops=plan.shift_add_ops,
        )


def enumerate_plans(
    in_features: int,
    out_features: int,
    fmt,
    modes: Sequence[str] = ("bitplane", "full"),
    max_index_bits: int = 24,
    max_chunk: int | None = None,
) -> list[PlanPoint]:
    """All uniform-chunk plans whose index width stays implementable."""
    points: list[PlanPoint] = []
    is_float = isinstance(fmt, Float16Format)
    for mode in modes:
        fpe = (
            (6 if mode == "bitplane" else 15)
            if is_float
            else (1 if mode == "bitplane" else fmt.total_bits)
        )
        hi = max_index_bits // fpe
        if max_chunk is not None:
            hi = min(hi, max_chunk)
        for m in range(1, max(hi, 0) + 1):
            if mode == "full" and is_float and m != 1:
                continue
            try:
                plan = LUTPlan(in_features, out_features, m, fmt, mode=mode)
            except ValueError:
                continue
            points.append(PlanPoint.of(plan))
    return points


def tradeoff_curve(points: Iterable[PlanPoint]) -> list[PlanPoint]:
    """Pareto frontier of (lut_bytes, shift_add_ops), sorted by size."""
    pts = sorted(points, key=lambda p: (p.lut_bytes, p.shift_add_ops))
    frontier: list[PlanPoint] = []
    best_ops = math.inf
    for p in pts:
        if p.shift_add_ops < best_ops:
            frontier.append(p)
            best_ops = p.shift_add_ops
    return frontier


def plan_under_budget(
    in_features: int,
    out_features: int,
    fmt,
    max_lut_bytes: int,
    modes: Sequence[str] = ("bitplane",),
) -> LUTPlan:
    """Fewest-ops plan whose tables fit the budget (raises if none fits)."""
    candidates = [
        p
        for p in enumerate_plans(in_features, out_features, fmt, modes=modes)
        if p.lut_bytes <= max_lut_bytes
    ]
    if not candidates:
        raise ValueError(
            f"no LUT plan for {in_features}x{out_features} fits "
            f"{max_lut_bytes} bytes"
        )
    return min(candidates, key=lambda p: (p.shift_add_ops, p.lut_bytes)).plan


def default_serving_plan(
    in_features: int, out_features: int, chunk_size: int = 4
) -> LUTPlan:
    """The plan LM serving uses unless a config overrides it: binary16 input
    (the paper's finding: fp16 inner activations preserve accuracy where
    fixed point does not), bitplane mode, moderate chunks."""
    return LUTPlan(in_features, out_features, chunk_size, Float16Format())
