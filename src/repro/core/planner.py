"""Partition planner: enumerate LUT configurations and their cost trade-off.

Reproduces the paper's size-vs-operations curves (Figs. 5, 7, 8) and picks a
plan under a memory budget.  All accounting is closed-form from
:class:`repro.core.lut.LUTPlan`; the formulas were validated against every
number the paper states for the linear classifier and the MLP (see
``tests/test_analysis.py``).

Beyond the per-layer helpers, :func:`plan_model` runs the whole-model pass:
it walks a parameter tree, enumerates the Pareto frontier of plans for every
eligible linear layer, and greedily spends a *global* LUT byte budget where
it buys the largest reduction in shift/add work — emitting a serializable
:class:`ModelPlan` that :func:`repro.core.convert.convert_params` applies
per layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Iterator, Mapping, Sequence, Union

from repro.core.lut import LUTPlan
from repro.core.lut_tl1 import TL1Plan
from repro.core.quantize import FixedPointFormat, Float16Format

# The two table families the pipeline is polymorphic over.  Both plan types
# expose the same accounting surface (num_chunks / total_lut_bytes /
# lut_evaluations / shift_add_ops / blocks), so a PlanPoint — and therefore
# the knapsack — treats them uniformly.
AnyPlan = Union[LUTPlan, TL1Plan]
TABLE_FAMILIES = ("weight", "tl1")


@dataclasses.dataclass(frozen=True)
class PlanPoint:
    plan: AnyPlan
    num_tables: int
    lut_bytes: int
    lut_evaluations: int
    shift_add_ops: int

    @staticmethod
    def of(plan: AnyPlan) -> "PlanPoint":
        return PlanPoint(
            plan=plan,
            num_tables=plan.num_chunks,
            lut_bytes=plan.total_lut_bytes,
            lut_evaluations=plan.lut_evaluations,
            shift_add_ops=plan.shift_add_ops,
        )


def _narrow_format_safe(fmt, mode: str) -> bool:
    """Whether i8/i16 storage with ONE power-of-2 scale per table set keeps
    quantization error at the int8-weight level.  True when table entries
    don't bake in the fp16 exponent range: the sigma-factored
    ``bitplane_shift`` tables span only ``[-(2**r - 1), 2**r - 1]`` times the
    weight range, and fixed-point bitplane tables are plain subset sums of
    weight rows.  Sigma-laden float tables (``bitplane`` / ``full``) span
    ~2**30 in magnitude across entries, which one 8/16-bit scale cannot
    represent — a narrow format there silently zeroes most entries."""
    if isinstance(fmt, Float16Format):
        return mode == "bitplane_shift"
    return mode == "bitplane"


def enumerate_plans(
    in_features: int,
    out_features: int,
    fmt,
    modes: Sequence[str] = ("bitplane", "full"),
    max_index_bits: int = 24,
    max_chunk: int | None = None,
    table_formats: Sequence[str | None] = (None,),
) -> list[PlanPoint]:
    """All uniform-chunk plans whose index width stays implementable.

    ``table_formats`` extends the frontier with narrow-storage variants
    (``"i8"`` / ``"i16"``); they are emitted only where single-scale
    quantization is accuracy-safe (see :func:`_narrow_format_safe`).
    """
    points: list[PlanPoint] = []
    is_float = isinstance(fmt, Float16Format)
    for mode in modes:
        if is_float:
            if mode == "bitplane":
                fpe = fmt.fields_per_element
            elif mode == "bitplane_shift":
                fpe = fmt.mantissa_radix + (1 if fmt.signed else 0)
            else:
                fpe = 15
        else:
            if mode == "bitplane_shift":
                continue  # float16-only mode
            fpe = 1 if mode == "bitplane" else fmt.total_bits
        hi = max_index_bits // fpe
        if max_chunk is not None:
            hi = min(hi, max_chunk)
        for m in range(1, max(hi, 0) + 1):
            if mode in ("full", "bitplane_shift") and is_float and m != 1:
                continue
            for table_format in table_formats:
                if table_format is not None and not _narrow_format_safe(fmt, mode):
                    continue
                try:
                    plan = LUTPlan(
                        in_features,
                        out_features,
                        m,
                        fmt,
                        mode=mode,
                        table_format=table_format,
                    )
                except ValueError:
                    continue
                points.append(PlanPoint.of(plan))
    return points


def tradeoff_curve(points: Iterable[PlanPoint]) -> list[PlanPoint]:
    """Pareto frontier of (lut_bytes, shift_add_ops), sorted by size."""
    pts = sorted(points, key=lambda p: (p.lut_bytes, p.shift_add_ops))
    frontier: list[PlanPoint] = []
    best_ops = math.inf
    for p in pts:
        if p.shift_add_ops < best_ops:
            frontier.append(p)
            best_ops = p.shift_add_ops
    return frontier


def plan_under_budget(
    in_features: int,
    out_features: int,
    fmt,
    max_lut_bytes: int,
    modes: Sequence[str] = ("bitplane",),
) -> LUTPlan:
    """Fewest-ops plan whose tables fit the budget (raises if none fits)."""
    candidates = [
        p
        for p in enumerate_plans(in_features, out_features, fmt, modes=modes)
        if p.lut_bytes <= max_lut_bytes
    ]
    if not candidates:
        raise ValueError(
            f"no LUT plan for {in_features}x{out_features} fits "
            f"{max_lut_bytes} bytes"
        )
    return min(candidates, key=lambda p: (p.shift_add_ops, p.lut_bytes)).plan


def default_serving_plan(
    in_features: int, out_features: int, chunk_size: int = 4
) -> LUTPlan:
    """The plan LM serving uses unless a config overrides it: binary16 input
    (the paper's finding: fp16 inner activations preserve accuracy where
    fixed point does not), bitplane mode, moderate chunks."""
    return LUTPlan(in_features, out_features, chunk_size, Float16Format())


# ---------------------------------------------------------------------------
# Whole-model planning: per-layer plans under a global byte budget
# ---------------------------------------------------------------------------


def _fmt_to_json(fmt) -> dict:
    if isinstance(fmt, Float16Format):
        out = {"kind": "float16", "signed": fmt.signed}
        if fmt.mantissa_radix != 1:
            out["mantissa_radix"] = fmt.mantissa_radix
        return out
    return {
        "kind": "fixed",
        "total_bits": fmt.total_bits,
        "frac_bits": fmt.frac_bits,
        "signed": fmt.signed,
    }


def _fmt_from_json(d: Mapping) -> Any:
    if d["kind"] == "float16":
        return Float16Format(
            signed=d["signed"], mantissa_radix=d.get("mantissa_radix", 1)
        )
    return FixedPointFormat(d["total_bits"], d["frac_bits"], signed=d["signed"])


def plan_to_json(plan: AnyPlan) -> dict:
    if isinstance(plan, TL1Plan):
        out = {
            "family": "tl1",
            "in_features": plan.in_features,
            "out_features": plan.out_features,
            "act_bits": plan.act_bits,
        }
        if plan.blocks is not None:
            out["blocks"] = list(plan.blocks)
        # acc contract rides checkpoints like blocks; defaults stay implicit
        # so pre-contract plan JSON and its golden files keep round-tripping.
        if plan.act_bits is not None and plan.acc_dtype != "int32":
            out["acc_dtype"] = plan.acc_dtype
        if plan.max_abs_acc is not None:
            out["max_abs_acc"] = plan.max_abs_acc
        return out
    out = {
        "in_features": plan.in_features,
        "out_features": plan.out_features,
        "chunk_size": plan.chunk_size,
        "fmt": _fmt_to_json(plan.fmt),
        "mode": plan.mode,
        "out_bits": plan.out_bits,
    }
    if plan.table_format is not None:
        out["table_format"] = plan.table_format
    if plan.blocks is not None:
        out["blocks"] = list(plan.blocks)
    if plan.acc_dtype != "float32":
        out["acc_dtype"] = plan.acc_dtype
    if plan.max_abs_acc is not None:
        out["max_abs_acc"] = plan.max_abs_acc
    return out


def plan_from_json(d: Mapping) -> AnyPlan:
    # "family" is absent from plans serialized before the TL1 family existed;
    # those are all weight-family, so the default keeps old ModelPlan JSON
    # (and the checkpoints it rides on) loading unchanged.
    family = d.get("family", "weight")
    blocks = d.get("blocks")
    blocks = tuple(blocks) if blocks is not None else None
    if family == "tl1":
        return TL1Plan(
            d["in_features"],
            d["out_features"],
            act_bits=d.get("act_bits", 8),
            blocks=blocks,
            acc_dtype=d.get("acc_dtype", "int32"),
            max_abs_acc=d.get("max_abs_acc"),
        )
    if family != "weight":
        raise ValueError(f"unknown table family {family!r}")
    return LUTPlan(
        d["in_features"],
        d["out_features"],
        d["chunk_size"],
        _fmt_from_json(d["fmt"]),
        mode=d["mode"],
        out_bits=d["out_bits"],
        table_format=d.get("table_format"),
        blocks=blocks,
        acc_dtype=d.get("acc_dtype", "float32"),
        max_abs_acc=d.get("max_abs_acc"),
    )


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    """Per-layer LUT plans keyed by the layer's ``"/"``-joined tree path.

    ``groups`` lists the fusable sibling sets (tuples of layer path keys)
    the plan was built around: every member of a group carries the *same*
    ``LUTPlan`` (the knapsack upgrades groups atomically), and
    ``convert_params`` emits each one as a single pre-stacked
    ``core.convert.LUTGroup`` node.

    ``copies`` records, per entry, the product of the weight's leading
    scan/expert dims — how many table SETS the converter builds for it
    (missing keys mean 1).  ``total_lut_bytes`` / ``total_shift_add_ops``
    scale by it, so a plan's totals match the bytes a conversion actually
    materialises (the pre-fix planner charged one ``(q, p)`` table per
    entry and could blow a budget by the expert count).

    JSON-serializable (``to_json``/``from_json``) so it rides along with
    checkpoints (``dist.checkpoint.save_checkpoint(..., aux=...)``) and
    reconverts identically after an elastic restore.
    """

    layers: Mapping[str, AnyPlan]
    budget_bytes: int | None = None
    groups: tuple = ()  # tuple[tuple[str, ...], ...] of layer path keys
    copies: Mapping[str, int] = dataclasses.field(default_factory=dict)

    @property
    def families(self) -> tuple[str, ...]:
        """Distinct table families present, in TABLE_FAMILIES order."""
        present = {p.table_family for p in self.layers.values()}
        return tuple(f for f in TABLE_FAMILIES if f in present)

    @property
    def total_lut_bytes(self) -> int:
        return sum(
            self.copies.get(k, 1) * p.total_lut_bytes for k, p in self.layers.items()
        )

    @property
    def total_shift_add_ops(self) -> int:
        return sum(
            self.copies.get(k, 1) * p.shift_add_ops for k, p in self.layers.items()
        )

    def to_json(self) -> dict:
        return {
            "budget_bytes": self.budget_bytes,
            "layers": {k: plan_to_json(p) for k, p in sorted(self.layers.items())},
            "groups": [list(g) for g in self.groups],
            "copies": {k: v for k, v in sorted(self.copies.items()) if v != 1},
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "ModelPlan":
        return cls(
            layers={k: plan_from_json(v) for k, v in d["layers"].items()},
            budget_bytes=d.get("budget_bytes"),
            groups=tuple(tuple(g) for g in d.get("groups", [])),
            copies=dict(d.get("copies", {})),
        )

    def summary(self) -> str:
        return (
            f"ModelPlan: {len(self.layers)} layers "
            f"({len(self.groups)} fused groups, "
            f"families {'+'.join(self.families) or 'none'}), "
            f"{self.total_lut_bytes / 2**20:.1f} MiB tables, "
            f"{self.total_shift_add_ops:,} shift/add ops"
        )


def path_key(path: Sequence) -> str:
    return "/".join(str(p) for p in path)


def _copies(w) -> int:
    """Table instances one weight leaf expands to: the product of its
    leading (scan-layer / expert) dims.  A ``(q, p)`` linear is 1 table set;
    a scan-stacked ``(L, q, p)`` builds L; an expert stack ``(L, E, q, p)``
    builds L*E — the converter vmaps ``build_luts`` over every leading dim,
    so bytes scale by exactly this factor (the pre-fix planner charged 1)."""
    return int(math.prod(int(d) for d in w.shape[:-2]))


def iter_linear_layers(
    params: dict,
    min_features: int = 1,
    predicate: Callable[[tuple, dict], bool] | None = None,
    convert_experts: bool = False,
) -> Iterator[tuple[str, tuple[int, int], int]]:
    """Yield ``(path_key, (in_features, out_features), copies)`` for every
    linear node ``convert_params`` would convert (same eligibility rules);
    ``copies`` is the product of the leading scan/expert dims — the number
    of table sets the converter actually builds for the entry.

    With ``convert_experts=True`` the raw MoE expert-stack weights are
    enumerated too (as ``.../w_gate`` etc.), mirroring
    ``convert_params(convert_experts=True)`` — the converter raises if a
    plan carries entries it never consumes, so keep the two flags in sync.
    """
    # local imports: avoid an import cycle with repro.core.convert
    from repro.core.convert import (
        EXPERT_WEIGHT_KEYS,
        _is_expert_stack,
        _is_linear_node,
    )

    def eligible(path: tuple, node: dict) -> bool:
        q = node["w"].shape[-2]
        return q >= min_features and (predicate is None or predicate(path, node))

    def walk(path: tuple, node: Any):
        if _is_linear_node(node):
            if eligible(path, node):
                q, p = node["w"].shape[-2:]
                yield path_key(path), (int(q), int(p)), _copies(node["w"])
            return
        if not isinstance(node, dict):
            return
        if convert_experts and _is_expert_stack(node):
            for k, v in node.items():
                if k in EXPERT_WEIGHT_KEYS:
                    mpath = path + (k,)
                    if eligible(mpath, {"w": v}):
                        q, p = v.shape[-2:]
                        yield path_key(mpath), (int(q), int(p)), _copies(v)
                else:
                    yield from walk(path + (k,), v)
            return
        for k in node:
            yield from walk(path + (k,), node[k])

    yield from walk((), params)


def iter_sibling_groups(
    params: dict,
    min_features: int = 1,
    predicate: Callable[[tuple, dict], bool] | None = None,
    convert_experts: bool = False,
) -> Iterator[tuple[str, ...]]:
    """Yield fusable sibling groups as tuples of layer path keys — the same
    detection ``convert_params(group_siblings=True)`` runs (shared helpers),
    restricted to members that pass the eligibility rules.  With
    ``convert_experts=True``, same-shape expert-stack pairs (gate/up) are
    yielded too, mirroring the converter's expert pre-stacking."""
    from repro.core.convert import (
        EXPERT_WEIGHT_KEYS,
        _is_expert_stack,
        _is_linear_node,
        expert_sibling_groups,
        sibling_groups,
    )

    def eligible(path: tuple, node: dict) -> bool:
        q = node["w"].shape[-2]
        return q >= min_features and (predicate is None or predicate(path, node))

    def walk(path: tuple, node: Any):
        if not isinstance(node, dict) or _is_linear_node(node):
            return
        if _is_expert_stack(node):
            if convert_experts:
                for members in expert_sibling_groups(node):
                    if all(eligible(path + (m,), {"w": node[m]}) for m in members):
                        yield tuple(path_key(path + (m,)) for m in members)
            for k, v in node.items():
                if k not in EXPERT_WEIGHT_KEYS:
                    yield from walk(path + (k,), v)
            return
        for members in sibling_groups(node):
            if all(eligible(path + (m,), node[m]) for m in members):
                yield tuple(path_key(path + (m,)) for m in members)
        for k, v in node.items():
            yield from walk(path + (k,), v)

    yield from walk((), params)


def plan_model(
    params: dict,
    max_lut_bytes: int | float,
    fmt=None,
    modes: Sequence[str] = ("bitplane",),
    max_chunk: int | None = None,
    min_features: int = 1,
    predicate: Callable[[tuple, dict], bool] | None = None,
    signed: bool = True,
    group_siblings: bool = True,
    convert_experts: bool = False,
    radices: Sequence[int] = (1,),
    table_formats: Sequence[str | None] = (None,),
    families: Sequence[str] = ("weight",),
    tl1_act_bits: int | None = 8,
    tl1_acc_dtype: str = "int32",
) -> ModelPlan:
    """Choose a per-layer plan for every eligible linear under a global budget.

    Greedy knapsack over each item's Pareto frontier: every item starts at
    its smallest-bytes plan; the budget is then spent on whichever single
    item upgrade buys the most shift/add reduction per byte (ties broken by
    smallest byte cost, then path order — fully deterministic).  The
    accuracy proxy is the format itself: binary16 bitplane plans are exact
    for fp16 inputs at *every* chunk size, so within one format the search
    reduces to bytes-vs-ops; narrower fixed-point formats trade accuracy and
    are selected by passing a different ``fmt``.

    Bytes and ops are charged per table SET actually built: an entry whose
    weight carries leading scan/expert dims (``(L, q, p)`` scan stacks,
    ``(L, E, q, p)`` expert stacks) costs its per-set bytes times the
    product of those dims, recorded on ``ModelPlan.copies`` — so a
    converted tree's ``ConvertReport.table_bytes`` (at the accounting
    ``out_bits`` width, i.e. fp16 tables) can never exceed the budget.

    With ``group_siblings=True`` (default) fusable sibling projections
    (QKV / K-V / gate-up — see ``core.convert.FUSABLE_SIBLINGS``; with
    ``convert_experts=True`` also expert gate/up stacks) form ONE knapsack
    item: their bytes and ops are accounted together and an upgrade moves
    every member at once, so the knapsack can never split a group onto
    different plans and silently defeat conversion-time fusion.  The group
    memberships are recorded on ``ModelPlan.groups``.

    ``radices`` widens the frontier with multi-bit mantissa-plane variants
    of a Float16 ``fmt`` (``Float16Format.mantissa_radix``) and
    ``table_formats`` with narrow table storage (``"i8"``/``"i16"``, where
    accuracy-safe) — both default to the paper's setting so the frontier
    only widens when a caller opts in.

    ``families`` widens the frontier across TABLE FAMILIES: with ``"tl1"``
    included, every item's frontier also carries the activation-side TL1
    point (ternary weights packed to base-3 indices, ``q*p/4`` persistent
    bytes, ``tl1_act_bits`` activation quantization) so each layer/group
    independently lands on weight-table vs TL1 under the one global byte
    budget.  TL1 is the smallest-bytes point by an order of magnitude;
    upgrades move individual items to weight-table plans wherever the
    budget buys the most shift/add reduction — so one model mixes families.

    Every candidate must additionally pass its *range certificate*
    (``repro.audit.ranges.layer_range_cert``): candidates whose proved
    worst-case |accumulator| exceeds the declared accumulator dtype's
    capacity (``tl1_acc_dtype`` for TL1 points, fp32 for weight tables)
    are rejected before the knapsack sees them, and the survivors are
    stamped with the proved bound (``max_abs_acc``) so kernels can assert
    the contract at trace time and checkpoints carry the proof.

    Raises ``ValueError`` if even the minimal per-layer plans exceed
    ``max_lut_bytes``, or if every candidate for some layer fails its
    accumulator certificate.
    """
    # call-time import: repro.audit imports this module (points builds
    # plans), so the certificate pass must not close the cycle at import.
    from repro.audit.ranges import layer_range_cert
    from repro.kernels.common import acc_capacity
    families = tuple(families)
    if not families or any(f not in TABLE_FAMILIES for f in families):
        raise ValueError(
            f"families must be a non-empty subset of {TABLE_FAMILIES}, "
            f"got {families}"
        )
    fmt = fmt if fmt is not None else Float16Format(signed=signed)
    if isinstance(fmt, Float16Format):
        fmt_variants = [
            dataclasses.replace(fmt, mantissa_radix=r) for r in sorted(set(radices))
        ]
    else:
        fmt_variants = [fmt]
    entries = list(
        iter_linear_layers(params, min_features, predicate, convert_experts)
    )
    shapes = {key: shape for key, shape, _ in entries}
    copies = {key: n for key, _, n in entries}
    groups: list[tuple[str, ...]] = (
        sorted(iter_sibling_groups(params, min_features, predicate, convert_experts))
        if group_siblings
        else []
    )
    in_group = {key for g in groups for key in g}
    # a knapsack item is a group (all members move together) or a lone layer;
    # its weight is the SUM of the members' table-set counts — a scan-stacked
    # or expert entry pays bytes/ops once per leading-dim instance, so an
    # expert stack is one atomic item spanning all E (or L*E) experts
    items: list[tuple[str, ...]] = groups + [
        (key,) for key in shapes if key not in in_group
    ]
    items.sort()
    mult = {item: sum(copies[k] for k in item) for item in items}

    frontiers: dict[tuple[str, ...], list[PlanPoint]] = {}
    frontier_cache: dict[tuple[int, int], list[PlanPoint]] = {}
    for item in items:
        q, p = shapes[item[0]]
        assert all(shapes[k] == (q, p) for k in item), item
        if (q, p) not in frontier_cache:
            pts = []
            if "weight" in families:
                pts += [
                    pt
                    for fv in fmt_variants
                    for pt in enumerate_plans(
                        q,
                        p,
                        fv,
                        modes=modes,
                        max_chunk=max_chunk,
                        table_formats=table_formats,
                    )
                ]
            if "tl1" in families:
                pts.append(
                    PlanPoint.of(
                        TL1Plan(q, p, act_bits=tl1_act_bits, acc_dtype=tl1_acc_dtype)
                    )
                )
            # certificate gate: drop candidates whose proved |acc| bound
            # overflows their declared accumulator; stamp the survivors.
            kept, rejected = [], []
            for pt in pts:
                cert = layer_range_cert(pt.plan)
                if cert.max_abs_acc > acc_capacity(pt.plan.acc_dtype):
                    rejected.append((pt.plan, cert))
                else:
                    kept.append(
                        PlanPoint.of(
                            dataclasses.replace(
                                pt.plan, max_abs_acc=cert.max_abs_acc
                            )
                        )
                    )
            if not kept and rejected:
                plan, cert = rejected[0]
                raise ValueError(
                    f"no overflow-safe plan for {q}x{p}: e.g. "
                    f"{type(plan).__name__} proves |acc| <= "
                    f"{cert.max_abs_acc:.6g}, which overflows "
                    f"acc_dtype={plan.acc_dtype!r} (capacity "
                    f"{acc_capacity(plan.acc_dtype):.6g}; minimal safe "
                    f"dtype {cert.min_acc_dtype})"
                )
            frontier_cache[(q, p)] = tradeoff_curve(kept)
        frontier = frontier_cache[(q, p)]
        if not frontier:
            raise ValueError(f"no feasible LUT plan for {item[0]} ({q}x{p})")
        frontiers[item] = frontier

    choice = {item: 0 for item in items}
    spent = sum(mult[item] * frontiers[item][0].lut_bytes for item in items)
    if spent > max_lut_bytes:
        raise ValueError(
            f"budget {max_lut_bytes} bytes < minimal model footprint "
            f"{spent} bytes ({len(shapes)} layers)"
        )

    while True:
        best = None  # (ops_saved_per_byte, -bytes_added, item, frontier index)
        for item in items:
            fr = frontiers[item]
            cur = fr[choice[item]]
            for j in range(choice[item] + 1, len(fr)):
                d_bytes = mult[item] * (fr[j].lut_bytes - cur.lut_bytes)
                if spent + d_bytes > max_lut_bytes:
                    break  # frontier bytes increase monotonically
                d_ops = mult[item] * (cur.shift_add_ops - fr[j].shift_add_ops)
                score = (d_ops / d_bytes, -d_bytes)
                if best is None or score > best[:2]:
                    best = (*score, item, j)
        if best is None:
            break
        _, _, item, j = best
        spent += mult[item] * (
            frontiers[item][j].lut_bytes - frontiers[item][choice[item]].lut_bytes
        )
        choice[item] = j

    layers = {
        key: frontiers[item][choice[item]].plan for item in items for key in item
    }
    budget = None if math.isinf(max_lut_bytes) else int(max_lut_bytes)
    return ModelPlan(
        layers=dict(sorted(layers.items())),
        budget_bytes=budget,
        groups=tuple(groups),
        copies={k: v for k, v in sorted(copies.items()) if v != 1},
    )
