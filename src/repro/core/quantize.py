"""Number formats and quantizers for TableNet LUT inputs.

The paper's LUT input set ``I`` is a low-resolution number format.  Two
families are implemented, both with *exact* bit-level decompositions so the
LUT path can be validated against a reference matmul:

* :class:`FixedPointFormat` — n-bit fixed point, signed (two's complement)
  or unsigned, with ``frac_bits`` fractional bits.  Bitplane ``j`` of the
  stored code contributes ``bit * 2**(j - frac_bits)`` (the MSB of a signed
  code contributes ``-2**(n-1-frac_bits)``, the paper's subtract-shifted-MSB
  trick).
* :class:`Float16Format` — IEEE 754 binary16.  Mantissa is decomposed into
  11 bitplanes (10 stored + the implicit leading bit); the full 5-bit
  exponent indexes the LUT alongside each mantissa bit.  Plane ``j`` of
  element ``x`` contributes ``bit * 2**j * sigma(e)`` with
  ``sigma(e) = 2**(max(e,1) - 25)`` — exact for normals *and* subnormals.

Quantizers are jit-friendly (pure jnp) and expose straight-through-estimator
variants for quantization-aware training, plus the paper's stochastic
rounding (threefry-counter based rather than a hardware mod-R counter, so
training steps stay replayable under fault tolerance).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Fixed point
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """``total_bits``-wide fixed point with ``frac_bits`` fractional bits."""

    total_bits: int
    frac_bits: int
    signed: bool = False

    def __post_init__(self):
        if not (1 <= self.total_bits <= 24):
            raise ValueError(f"total_bits must be in [1, 24], got {self.total_bits}")

    # -- ranges -------------------------------------------------------------
    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def code_min(self) -> int:
        return -(2 ** (self.total_bits - 1)) if self.signed else 0

    @property
    def code_max(self) -> int:
        return 2 ** (self.total_bits - 1) - 1 if self.signed else 2**self.total_bits - 1

    @property
    def min_value(self) -> float:
        return self.code_min * self.scale

    @property
    def max_value(self) -> float:
        return self.code_max * self.scale

    @property
    def num_planes(self) -> int:
        return self.total_bits

    # -- core ops -------------------------------------------------------------
    def quantize(self, x: jax.Array) -> jax.Array:
        """float -> integer code (round-to-nearest-even, saturating)."""
        c = jnp.round(x / self.scale)
        c = jnp.clip(c, self.code_min, self.code_max)
        return c.astype(jnp.int32)

    def quantize_stochastic(self, x: jax.Array, key: jax.Array) -> jax.Array:
        """Paper §Stochastic rounding: P(up) = frac(x/eps)."""
        v = x / self.scale
        lo = jnp.floor(v)
        p_up = v - lo
        u = jax.random.uniform(key, x.shape)
        c = lo + (u < p_up).astype(lo.dtype)
        return jnp.clip(c, self.code_min, self.code_max).astype(jnp.int32)

    def dequantize(self, codes: jax.Array) -> jax.Array:
        return codes.astype(jnp.float32) * self.scale

    def fake_quant(self, x: jax.Array) -> jax.Array:
        """Quantize+dequantize with straight-through gradient (for QAT)."""
        y = self.dequantize(self.quantize(x))
        return x + jax.lax.stop_gradient(y - x)

    # -- bit-level views ------------------------------------------------------
    def to_unsigned_bits(self, codes: jax.Array) -> jax.Array:
        """Two's-complement bit pattern of the code as a non-negative int."""
        if self.signed:
            return jnp.where(codes < 0, codes + 2**self.total_bits, codes).astype(
                jnp.int32
            )
        return codes.astype(jnp.int32)

    def bitplanes(self, codes: jax.Array) -> jax.Array:
        """Return bits with a new leading axis of size ``num_planes``.

        ``value(codes) == sum_j plane_scales()[j] * bits[j]`` exactly.
        """
        u = self.to_unsigned_bits(codes)
        planes = jnp.arange(self.num_planes, dtype=jnp.int32)
        return (u[None, ...] >> planes.reshape((-1,) + (1,) * u.ndim)) & 1

    def plane_scales(self) -> np.ndarray:
        """Per-plane multiplier; MSB is negative for signed formats."""
        s = (2.0 ** np.arange(self.num_planes)) * self.scale
        if self.signed:
            s[-1] = -s[-1]
        return s.astype(np.float64)


# ---------------------------------------------------------------------------
# IEEE binary16
# ---------------------------------------------------------------------------

_F16_EXP_BITS = 5
_F16_MAN_BITS = 10
_F16_BIAS = 15


@dataclasses.dataclass(frozen=True)
class Float16Format:
    """binary16 LUT input format.

    ``signed=False`` is the paper's setting (sign bit always 0 after ReLU,
    halving the tables); ``signed=True`` extends the paper's scheme the way
    it handles fixed point signs — the sign bit joins the exponent in every
    LUT field (7 index bits/element), needed for LM layers whose inputs are
    norm/residual activations rather than ReLU outputs.

    ``mantissa_radix=r`` groups ``r`` mantissa bits per plane instead of the
    paper's 1: ``ceil(11/r)`` planes, each LUT field carrying an ``r``-bit
    mantissa slice next to the exponent, plane scales ``(2**r)**j``.  The
    decomposition stays *exact* (the planes partition the same 11 mantissa
    bits) and the accumulate stays shift-and-add — a shift by ``r*j`` in
    hardware — but each table gains ``2**(r-1)`` entries per element.  It is
    the memory-for-evaluations trade orthogonal to chunk size: radix trades
    bits *within* an element, chunking trades elements *within* an index.
    """

    signed: bool = False
    mantissa_radix: int = 1

    def __post_init__(self):
        if not (1 <= self.mantissa_radix <= _F16_MAN_BITS + 1):
            raise ValueError(
                f"mantissa_radix must be in [1, {_F16_MAN_BITS + 1}], "
                f"got {self.mantissa_radix}"
            )

    @property
    def exp_bits(self) -> int:
        return _F16_EXP_BITS

    @property
    def num_planes(self) -> int:
        # 10 stored mantissa bits + the implicit leading bit, radix at a time.
        return -(-(_F16_MAN_BITS + 1) // self.mantissa_radix)

    @property
    def fields_per_element(self) -> int:
        # mantissa slice + full exponent (+ sign) index the LUT (paper Fig. 1).
        return self.mantissa_radix + _F16_EXP_BITS + (1 if self.signed else 0)

    def quantize(self, x: jax.Array) -> jax.Array:
        """float -> binary16 (unsigned mode clamps negatives to 0)."""
        if self.signed:
            return x.astype(jnp.float16)
        return jnp.maximum(x, 0.0).astype(jnp.float16)

    def fake_quant(self, x: jax.Array) -> jax.Array:
        y = self.quantize(x).astype(jnp.float32)
        return x + jax.lax.stop_gradient(y - x)

    def dequantize(self, h: jax.Array) -> jax.Array:
        return h.astype(jnp.float32)

    # -- bit-level views ------------------------------------------------------
    def decompose(self, h: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Return ``(exponent, mantissa_planes)``.

        ``exponent`` is int32 with shape of ``h``; ``mantissa_planes`` has a
        leading axis of ``num_planes`` values, each the ``mantissa_radix``-bit
        slice ``j`` of the 11-bit mantissa (10 stored bits plus the implicit
        leading bit, which is 1 iff the number is normal).  At the default
        radix 1, plane 10 is the implicit bit.
        """
        r = self.mantissa_radix
        bits = jax.lax.bitcast_convert_type(h.astype(jnp.float16), jnp.uint16).astype(
            jnp.int32
        )
        exp = (bits >> _F16_MAN_BITS) & (2**_F16_EXP_BITS - 1)
        man = bits & (2**_F16_MAN_BITS - 1)
        man = man | ((exp > 0).astype(jnp.int32) << _F16_MAN_BITS)
        shifts = r * jnp.arange(self.num_planes, dtype=jnp.int32)
        slices = (man[None, ...] >> shifts.reshape((-1,) + (1,) * man.ndim)) & (
            2**r - 1
        )
        return exp, slices

    @staticmethod
    def sign_bits(h: jax.Array) -> jax.Array:
        bits = jax.lax.bitcast_convert_type(h.astype(jnp.float16), jnp.uint16)
        return (bits.astype(jnp.int32) >> 15) & 1

    @staticmethod
    def sigma(exp: jax.Array | np.ndarray) -> jax.Array | np.ndarray:
        """Per-element scale so that value == sum_j 2**j * bit_j * sigma(e)."""
        e = jnp.maximum(exp, 1) if isinstance(exp, jax.Array) else np.maximum(exp, 1)
        return 2.0 ** (e.astype(jnp.float32) - (_F16_BIAS + _F16_MAN_BITS))

    def plane_scales(self) -> np.ndarray:
        r = self.mantissa_radix
        return (2.0 ** (r * np.arange(self.num_planes))).astype(np.float64)


# ---------------------------------------------------------------------------
# Ternary weights (TL1 / BitNet-style activation-side tables)
# ---------------------------------------------------------------------------


def ternary_quantize(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absmean ternarisation of a weight matrix: ``w ~= s * t``, t in {-1,0,+1}.

    ``t = clip(round(w / mean|w|), -1, 1)`` picks the codes; the scale is
    then re-fit in closed form (least squares over the chosen codes),
    ``s = <w, t> / <t, t>``.  The refit makes the quantizer *idempotent*:
    ``ternary_quantize(s * t) == (t, s)`` exactly, which the TL1 stream-
    equivalence tests rely on (ternarise once, serve dense and TL1 from the
    same values).

    Returns ``(t, s)`` with ``t`` int8 of ``w``'s shape and ``s`` a float32
    scalar (per call — vmap over leading dims for stacked weights).
    """
    w = jnp.asarray(w, jnp.float32)
    s0 = jnp.maximum(jnp.mean(jnp.abs(w)), 1e-12)
    t = jnp.clip(jnp.round(w / s0), -1.0, 1.0)
    s = jnp.sum(w * t) / jnp.maximum(jnp.sum(t * t), 1.0)
    return t.astype(jnp.int8), s.astype(jnp.float32)


def ternary_fake_quant(w: jax.Array) -> jax.Array:
    """``s * t`` at ``w``'s dtype — the dense stand-in for a TL1 layer."""
    t, s = ternary_quantize(w)
    return (s * t.astype(jnp.float32)).astype(w.dtype)


def absmax_int_quantize(
    x: jax.Array, bits: int = 8, axis: int = -1
) -> tuple[jax.Array, jax.Array]:
    """Per-token symmetric absmax quantization of activations.

    Returns ``(q, scale)`` with ``q`` int32 codes in ``[-(2**(bits-1)-1),
    2**(bits-1)-1]`` and ``scale`` shaped like ``x`` with ``axis`` kept at
    size 1, so ``x ~= q * scale``.
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Stochastic rounding as a LUT (paper §Stochastic rounding)
# ---------------------------------------------------------------------------


def build_stochastic_rounding_lut(
    fmt: FixedPointFormat, in_bits: int, R: int, seed: int = 0
) -> np.ndarray:
    """Materialise the paper's rounding LUT: index = (code, counter mod R).

    Maps an ``in_bits`` fixed point code (same frac_bits and signedness as
    ``fmt``) down to ``fmt``; the random sequence r(i) is fixed at build
    time.  Size is ``R * 2**in_bits`` output codes — the paper's
    ``R * 2**beta(I) * beta(O)`` bits.

    Columns are indexed by the input code's ``in_bits``-wide BIT PATTERN.
    For a signed ``fmt`` the pattern is interpreted as two's complement, so
    negative codes floor toward -inf (arithmetic shift), round up with the
    same ``P(up) = frac`` rule, and saturate at ``fmt.code_min`` — the
    pre-fix table treated every pattern as unsigned and clipped to
    ``[0, code_max]``, silently zero-clamping all negative inputs.
    """
    if in_bits <= fmt.total_bits:
        raise ValueError("input format must be wider than the output format")
    rng = np.random.default_rng(seed)
    r = rng.uniform(size=R)
    shift = in_bits - fmt.total_bits
    codes = np.arange(2**in_bits, dtype=np.int64)
    if fmt.signed:  # columns are bit patterns: decode two's complement
        codes = codes - (codes >= 2 ** (in_bits - 1)) * 2**in_bits
    lo = codes >> shift  # arithmetic shift == floor for negatives
    frac = (codes & (2**shift - 1)) / float(2**shift)
    # f(x, i) = floor(x) if r(i) <= 1 - frac else floor(x)+eps
    table = lo[None, :] + (r[:, None] > 1.0 - frac[None, :]).astype(np.int64)
    return np.clip(table, fmt.code_min, fmt.code_max).astype(np.int32)


def stochastic_round_via_lut(table: np.ndarray, codes: jax.Array, step: jax.Array):
    """Apply the rounding LUT with a replayable counter (step index).

    ``codes`` may be signed: the column index is the code's two's-complement
    bit pattern (negative codes wrap modulo the table width), matching how
    :func:`build_stochastic_rounding_lut` lays out its columns.
    """
    R, width = table.shape
    i = jnp.asarray(step, jnp.int32) % R
    cols = jnp.where(codes < 0, codes + width, codes)
    return jnp.asarray(table)[i, cols]
