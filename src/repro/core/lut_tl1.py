"""TL1 activation-side look-up tables (the second table family).

The weight-side family in :mod:`repro.core.lut` builds ``2**index_bits``-entry
tables *from the weights* at convert time and indexes them with activation
codes.  TL1 (SNIPPETS snippet 1, BitNet lineage) inverts that layout:

* **Convert time** — weights are ternarised (absmean, −1/0/+1, one fp32 scale
  per weight matrix) and every *pair* of ternary weights along the input axis
  collapses into a base-3 index ``(t0+1)*3 + (t1+1)`` in ``0..8``.  Two such
  4-bit indices pack per byte (low nibble first), so the persistent table
  leaf is ``ceil(ceil(q/2)/2) x p`` uint8 — ``q*p/4`` bytes, radically
  smaller than any weight-side table.
* **Decode time** — activations are quantized per token (int8 absmax by
  default) and a tiny 9-entry LUT is built *per weight-pair chunk per step*:
  ``lut[c, i] = s0(i)*a[2c] + s1(i)*a[2c+1]`` with ``s(i) = i//3-1, i%3-1``.
  All nine entries are sums/differences of two activations — adds only.
  The matmul is then ``y[p] = s_w * s_a * sum_c lut[c, widx[c, p]]``:
  gathers and adds, no multiplies over weight-sized operands.

Entries are int16 (activations are int8 so each entry fits ±254); the
accumulator dtype is a *proved* per-plan contract, not folklore: the plan
carries ``acc_dtype``/``max_abs_acc`` and ``repro.audit.ranges`` certifies
``|acc| <= 2 * (2**(act_bits-1) - 1) * num_chunks`` statically (the "int16"
in the TL1 lineage refers to the table entries; the accumulator needs
whatever that bound demands — int32 for every real layer width).
``act_bits=None`` selects an exact fp32 variant
(no activation quantization; the adds are exact w.r.t. a dense matmul over
the ternarised weights) used by the stream-equivalence tests.

This module is the pure-jnp oracle; ``repro.kernels.lut_tl1`` implements the
same contract as Pallas kernels (plain + grouped) and is tested against it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.quantize import absmax_int_quantize, ternary_quantize


@dataclasses.dataclass(frozen=True)
class TL1Plan:
    """How one affine layer (q -> p) maps onto TL1 activation-side tables.

    Mirrors :class:`repro.core.lut.LUTPlan`'s accounting surface
    (``num_chunks`` / ``num_entries`` / ``lut_evaluations`` /
    ``shift_add_ops`` / ``total_lut_bytes`` / ``blocks``) so the planner's
    ``PlanPoint`` and the autotuner's ``TunePoint`` are family-polymorphic.
    """

    in_features: int  # q
    out_features: int  # p
    # Activation quantization width (per-token absmax).  None = exact fp32
    # activations (adds only, bit-exact vs dense over the ternary weights).
    act_bits: int | None = 8
    # Autotuned Pallas tile sizes (block_b, block_p, block_k) where block_k
    # counts *packed bytes* along the input axis; persisted via ModelPlan
    # JSON like the weight family's.
    blocks: tuple[int, int, int] | None = None
    # Accumulator contract: the integer dtype the kernels accumulate LUT
    # entries in (fp32 on the exact ``act_bits=None`` path) and the proved
    # worst-case |accumulator| in code units — ``2*(2**(act_bits-1)-1)*
    # num_chunks``, certified by ``repro.audit.ranges.layer_range_cert``
    # and stamped by ``plan_model``.  ``max_abs_acc`` is derived metadata,
    # excluded from equality like the weight family's.
    acc_dtype: str = "int32"
    max_abs_acc: float | None = dataclasses.field(default=None, compare=False)

    table_family = "tl1"

    def __post_init__(self):
        if self.act_bits is not None and not (2 <= int(self.act_bits) <= 8):
            raise ValueError(f"act_bits must be None or in [2, 8], got {self.act_bits}")
        if self.blocks is not None:
            object.__setattr__(self, "blocks", tuple(int(v) for v in self.blocks))
            if len(self.blocks) != 3 or any(v <= 0 for v in self.blocks):
                raise ValueError(f"blocks must be 3 positive ints, got {self.blocks}")
        if self.acc_dtype not in ("int16", "int32", "float32"):
            raise ValueError(f"unknown acc_dtype {self.acc_dtype!r}")
        if self.act_bits is None:
            # the exact path's codes are fp32, so every kernel (and the
            # oracle's _accumulate) accumulates fp32 — normalising here
            # keeps the declared contract truthful for exact plans.
            object.__setattr__(self, "acc_dtype", "float32")
        if self.max_abs_acc is not None:
            object.__setattr__(self, "max_abs_acc", float(self.max_abs_acc))
            if self.max_abs_acc < 0:
                raise ValueError(f"max_abs_acc must be >= 0, got {self.max_abs_acc}")

    # -- derived sizes --------------------------------------------------------
    @property
    def chunk_size(self) -> int:  # input elements per index
        return 2

    @property
    def num_chunks(self) -> int:  # k: weight pairs (4-bit indices)
        return -(-self.in_features // 2)

    @property
    def packed_chunks(self) -> int:  # kb: bytes per output column
        return -(-self.num_chunks // 2)

    @property
    def padded_in(self) -> int:
        return 4 * self.packed_chunks

    @property
    def num_entries(self) -> int:  # 3**2 activation sums per chunk LUT
        return 9

    @property
    def num_planes(self) -> int:
        return 1

    # -- cost accounting ------------------------------------------------------
    @property
    def lut_evaluations(self) -> int:
        return self.num_chunks

    @property
    def shift_add_ops(self) -> int:
        """Adds per token: ``p*(k-1)`` accumulate + ``9k`` per-step LUT build
        (each of the 9 entries is at most one add of two activations)."""
        return self.out_features * (self.num_chunks - 1) + 9 * self.num_chunks

    @property
    def storage_bits(self) -> int:  # per packed *index pair* (one byte)
        return 8

    @property
    def total_lut_bits(self) -> int:
        """Persistent bytes only: the packed weight-index leaf.  The 9-entry
        activation LUT is transient per decode step (like the weight family's
        packed codes) and is deliberately not charged to the byte budget."""
        return self.packed_chunks * self.out_features * self.storage_bits

    @property
    def total_lut_bytes(self) -> int:
        return self.total_lut_bits // 8


# ---------------------------------------------------------------------------
# Packing (convert time)
# ---------------------------------------------------------------------------


def pack_ternary(t: jax.Array) -> jax.Array:
    """(q, p) ternary codes in {-1,0,+1} -> (kb, p) uint8 packed indices.

    Pairs along the input axis become base-3 indices ``(t0+1)*3 + (t1+1)``;
    two indices pack per byte, low nibble first (the exemplar's layout).
    The ragged tail pads with ternary 0, whose LUT entry is built from
    zero-padded activations — exact.
    """
    q, p = t.shape
    pad = -q % 4
    tp = jnp.pad(t.astype(jnp.int32), ((0, pad), (0, 0)))
    idx = (tp[0::2] + 1) * 3 + (tp[1::2] + 1)  # (k_pad, p) in 0..8
    return (idx[0::2] | (idx[1::2] << 4)).astype(jnp.uint8)  # (kb, p)


def unpack_indices(packed: jax.Array) -> jax.Array:
    """(..., kb, p) uint8 -> (..., 2*kb, p) int32 base-3 indices in 0..8."""
    b = packed.astype(jnp.int32)
    lo, hi = b & 15, b >> 4
    k2 = 2 * packed.shape[-2]
    stacked = jnp.stack([lo, hi], axis=-2)  # (..., kb, 2, p)
    return stacked.reshape(*packed.shape[:-2], k2, packed.shape[-1])


def build_tl1_tables(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(q, p) weights -> (packed (kb, p) uint8, scale () f32)."""
    t, s = ternary_quantize(w)
    return pack_ternary(t), s


# ---------------------------------------------------------------------------
# Application (decode time) — the oracle
# ---------------------------------------------------------------------------


def quantize_acts(x: jax.Array, plan: TL1Plan) -> tuple[jax.Array, jax.Array | None]:
    """(..., q) activations -> (codes (..., padded_in), per-token scale | None).

    int path: int32 codes + (..., 1) fp32 scale; exact path (``act_bits is
    None``): fp32 values, scale None.  Padding is zeros, so padded chunks
    contribute 0 through any LUT entry.
    """
    q = plan.in_features
    if x.shape[-1] != q:
        raise ValueError(f"activation width {x.shape[-1]} != plan in_features {q}")
    pad = plan.padded_in - q
    if plan.act_bits is None:
        a = jnp.asarray(x, jnp.float32)
        return jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)]), None
    codes, scale = absmax_int_quantize(x, bits=int(plan.act_bits), axis=-1)
    return jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)]), scale


def build_act_lut(acts: jax.Array) -> jax.Array:
    """(..., 2k) activation codes -> (..., k, 9) per-chunk LUT, adds only.

    Entry ``i`` of chunk ``c`` is ``s0*a[2c] + s1*a[2c+1]`` with
    ``s0 = i//3 - 1`` and ``s1 = i%3 - 1``.  int32 codes yield int16 entries
    (int8 activations sum within ±254); fp32 codes stay fp32.
    """
    a0, a1 = acts[..., 0::2], acts[..., 1::2]
    z = jnp.zeros_like(a0)
    lut = jnp.stack(
        [-a0 - a1, -a0, a1 - a0, -a1, z, a1, a0 - a1, a0, a0 + a1], axis=-1
    )
    return lut.astype(jnp.int16) if jnp.issubdtype(lut.dtype, jnp.integer) else lut


def _accumulate(lut: jax.Array, idx: jax.Array) -> jax.Array:
    """lut (..., k2, 9) x idx (k2, p) -> (..., p); int32 or fp32 accumulate."""
    p = idx.shape[-1]
    g = jnp.take_along_axis(lut, jnp.broadcast_to(idx, lut.shape[:-1] + (p,)), axis=-1)
    acc_dtype = jnp.int32 if jnp.issubdtype(g.dtype, jnp.integer) else jnp.float32
    return jnp.sum(g.astype(acc_dtype), axis=-2)


def apply_tl1(
    tables: jax.Array,
    x: jax.Array,
    plan: TL1Plan,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
    acts: tuple[jax.Array, jax.Array | None] | None = None,
) -> jax.Array:
    """Oracle TL1 affine: tables (kb, p) uint8, x (..., q) -> (..., p).

    ``scale`` is the ternary weight scale from conversion (defaults to 1).
    ``acts`` optionally carries pre-quantized activations (the grouped path
    shares one quantization across all members of a fused group).
    """
    codes, s_a = quantize_acts(x, plan) if acts is None else acts
    lut = build_act_lut(codes)
    acc = _accumulate(lut, unpack_indices(tables)).astype(jnp.float32)
    y = acc * s_a if s_a is not None else acc
    if scale is not None:
        y = y * scale
    if bias is not None:
        y = y + bias
    return y


def tl1_linear_reference(w: jax.Array, x: jax.Array, plan: TL1Plan, bias=None):
    """Convert-and-apply in one call (tests / accuracy bench convenience)."""
    packed, s = build_tl1_tables(w)
    return apply_tl1(packed, x, plan, bias=bias, scale=s)
