"""Model -> TableNet conversion pass.

Walks a trained parameter tree and replaces every eligible linear node
({"w": 2-D array} produced by ``models.layers.linear_spec``) with its LUT
tables, exactly as the paper prescribes post-training.  The zoo's
:func:`repro.models.layers.linear` then executes those layers via the LUT
path, so a converted model serves **multiplier-free** (in the paper's
arithmetic sense — see DESIGN.md §2) with no other code changes.

Non-affine recurrences (SSD / WKV — data-dependent transition weights) and
raw tensors (embeddings, routers, norm scales, 3-D expert stacks) are left
untouched; the expert stacks can be converted per-expert via
``convert_experts=True`` (vmapped table build).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import LUTPlan, build_luts
from repro.core.planner import ModelPlan, path_key
from repro.core.quantize import Float16Format


@dataclasses.dataclass(frozen=True)
class ConvertReport:
    converted: int
    skipped: int
    weight_bytes: int
    table_bytes: int


def _is_linear_node(node: Any) -> bool:
    # 2-D = plain linear; 3-D = scan-stacked (L, q, p) — both convertible
    return (
        isinstance(node, dict)
        and "w" in node
        and hasattr(node["w"], "ndim")
        and node["w"].ndim in (2, 3)
        and set(node) <= {"w", "b"}
    )


def _build_tables(w, plan: LUTPlan, dtype):
    """build_luts vmapped over any leading (layer/expert) dims."""

    def fn(m):
        return build_luts(m.astype(jnp.float32), plan)

    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w).astype(dtype)


def convert_params(
    params: dict,
    chunk_size: int = 1,
    min_features: int = 1,
    predicate: Callable[[tuple, dict], bool] | None = None,
    table_dtype=jnp.float32,
    convert_experts: bool = False,
    signed: bool = True,  # LM activations are signed; paper models may use False
    plan: Optional[ModelPlan] = None,
) -> tuple[dict, ConvertReport]:
    """Returns (converted tree, report).  ``predicate(path, node)`` can veto
    individual layers (default: convert everything eligible).

    With ``plan`` (a :class:`repro.core.planner.ModelPlan`, e.g. from
    ``plan_model``) each layer uses its *own* plan, looked up by tree path;
    layers absent from the plan are skipped.  Without it, one uniform
    ``(chunk_size, fp16-bitplane)`` plan applies everywhere.  Expert stacks
    (``convert_experts=True``) always use the uniform plan — ``plan_model``
    does not enumerate them.
    """
    stats = {"converted": 0, "skipped": 0, "w_bytes": 0, "t_bytes": 0}
    fmt = Float16Format(signed=signed)

    def walk(path: tuple, node: Any):
        if _is_linear_node(node):
            w = node["w"]
            q, p = w.shape[-2:]
            if q < min_features or (predicate and not predicate(path, node)):
                stats["skipped"] += 1
                return node
            if plan is not None:
                layer_plan = plan.layers.get(path_key(path))
                if layer_plan is None:
                    stats["skipped"] += 1
                    return node
                if (layer_plan.in_features, layer_plan.out_features) != (q, p):
                    raise ValueError(
                        f"plan for {path_key(path)} is "
                        f"{layer_plan.in_features}x{layer_plan.out_features}, "
                        f"layer is {q}x{p}"
                    )
            else:
                layer_plan = LUTPlan(q, p, chunk_size, fmt, mode="bitplane")
            tables = _build_tables(w, layer_plan, table_dtype)
            stats["converted"] += 1
            stats["w_bytes"] += w.size * w.dtype.itemsize
            stats["t_bytes"] += tables.size * tables.dtype.itemsize
            out = {"tables": tables}
            if "b" in node:
                out["b"] = node["b"]
            return out
        if convert_experts and isinstance(node, dict) and _is_expert_stack(node):
            node = _convert_expert_stack(node, chunk_size, table_dtype, stats, fmt)
            return {
                k: (v if k in ("w_gate", "w_up", "w_down") else walk(path + (k,), v))
                for k, v in node.items()
            }
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        return node

    out = walk((), params)
    report = ConvertReport(
        stats["converted"], stats["skipped"], stats["w_bytes"], stats["t_bytes"]
    )
    return out, report


def _is_expert_stack(node: dict) -> bool:
    return {"w_gate", "w_up", "w_down", "router"} <= set(node) and (
        hasattr(node["w_gate"], "ndim") and node["w_gate"].ndim in (3, 4)
    )


def _convert_expert_stack(node: dict, chunk: int, dtype, stats, fmt) -> dict:
    out = dict(node)
    for key in ("w_gate", "w_up", "w_down"):
        w3 = node[key]  # (E, q, p) or stacked (L, E, q, p)
        q, p = w3.shape[-2:]
        plan = LUTPlan(q, p, chunk, fmt, mode="bitplane")
        tables = _build_tables(w3, plan, dtype)
        out[key] = {"tables": tables}  # (..., E, k, entries, p)
        stats["converted"] += 1
        stats["w_bytes"] += w3.size * w3.dtype.itemsize
        stats["t_bytes"] += tables.size * np.dtype(dtype).itemsize
    return out


def conversion_summary(report: ConvertReport) -> str:
    ratio = report.table_bytes / max(report.weight_bytes, 1)
    return (
        f"converted {report.converted} linears ({report.skipped} skipped): "
        f"{report.weight_bytes / 2**20:.1f} MiB weights -> "
        f"{report.table_bytes / 2**20:.1f} MiB tables ({ratio:.0f}x)"
    )
