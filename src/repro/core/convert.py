"""Model -> TableNet conversion pass.

Walks a trained parameter tree and replaces every eligible linear node
({"w": 2-D array} produced by ``models.layers.linear_spec``) with its LUT
tables, exactly as the paper prescribes post-training.  The zoo's
:func:`repro.models.layers.linear` then executes those layers via the LUT
path, so a converted model serves **multiplier-free** (in the paper's
arithmetic sense — see DESIGN.md §2) with no other code changes.

Converted layout
----------------
A converted node is a registered pytree class carrying the tables *and an
explicit plan record* (chunk size / number format / mode) as static
metadata — execution never re-infers the plan from table shapes (shape
sniffing is genuinely ambiguous once fixed-point plans enter the picture:
an unsigned fixed-point chunk-7 bitplane table and a signed-fp16 chunk-1
table both have 2**7 entries).

* :class:`LUTLinear` — one projection: ``tables (..., k, entries, p)``.
* :class:`LUTGroup` — fusable sibling projections (QKV with equal head
  counts, K/V, gate/up) **pre-stacked at conversion time** into one
  ``tables (..., G, k, entries, p)`` leaf, replacing the member keys with
  a single ``"a+b"`` key.  Serving indexes the stored group directly — no
  per-decode-step stack/concat of table-sized operands ever appears under
  jit (asserted at the jaxpr level in ``tests/test_grouped_layout.py``).

Non-affine recurrences (SSD / WKV — data-dependent transition weights) and
raw tensors (embeddings, routers, norm scales) are left untouched; MoE
expert stacks are converted per-expert via ``convert_experts=True``
(vmapped table build) under the same eligibility rules
(``min_features``/``predicate``) the planner applies.  Same-shape expert
pairs (``w_gate``/``w_up``) pre-stack into one :class:`LUTGroup` whose
leaf is ``(..., E, G, k, entries, p)`` — the exact array
``kernels.lut_affine.lut_affine_experts`` consumes after the layer scan
slices the leading dim — and ``models.moe.moe_ffn`` executes converted
expert leaves via the ragged LUT path (codes packed once per token; the
``ragged_dot`` calls disappear from the decode program).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.lut import LUTPlan, build_luts, quantize_tables
from repro.core.lut_tl1 import TL1Plan, build_tl1_tables
from repro.core.planner import ModelPlan, path_key
from repro.core.quantize import Float16Format

AnyPlan = Union[LUTPlan, TL1Plan]

# Sibling key sets that execute against the SAME input at their call sites
# (models.layers.attention / models.layers.mlp / models.encdec) and are
# therefore fusable into one grouped dispatch.  Detection takes the maximal
# same-shape subset, so GQA (wq wider than wk/wv) still fuses K/V.
FUSABLE_SIBLINGS = (("wq", "wk", "wv"), ("w_gate", "w_up"))

EXPERT_WEIGHT_KEYS = ("w_gate", "w_up", "w_down")


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(eq=False)
class LUTLinear:
    """A converted projection: kernel-ready tables + its conversion plan.

    ``plan`` is pytree *aux data* (static under jit), so the execution path
    reads chunk/format/mode directly instead of sniffing table shapes.
    """

    # weight family: (..., k, entries, p) table entries.
    # tl1 family: (..., kb, p) uint8 packed base-3 weight-pair indices.
    tables: Any
    plan: AnyPlan
    b: Any = None  # (..., p) or None
    # Weight family: scalar power-of-2 dequant scale when
    # ``plan.table_format`` stores the tables narrow (i8/i16); None for
    # full-width tables.  TL1 family: the absmean ternary weight scale
    # (always present).  A leaf (not aux): it is data derived from the
    # weights, and it must ride checkpoints.
    scale: Any = None

    def tree_flatten_with_keys(self):
        return (
            (
                (jax.tree_util.GetAttrKey("tables"), self.tables),
                (jax.tree_util.GetAttrKey("b"), self.b),
                (jax.tree_util.GetAttrKey("scale"), self.scale),
            ),
            self.plan,
        )

    @classmethod
    def tree_unflatten(cls, plan, children):
        tables, b, scale = children
        return cls(tables, plan, b, scale)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(eq=False)
class LUTGroup:
    """Pre-stacked fusable sibling projections sharing one plan.

    ``tables`` holds every member's tables stacked on a group axis just
    before the chunk axis — ``(..., G, k, entries, p)`` — which is exactly
    the layout ``kernels.lut_affine.lut_affine_grouped`` consumes, so a
    grouped decode step reads the stored leaf with zero copies.

    ``b`` is ``None`` (no member has a bias), a stacked ``(..., G, p)``
    array (every member has one), or a per-member tuple with ``None``
    holes (mixed) — mixed-bias groups still fuse.
    """

    tables: Any  # (..., G, k, entries, p); tl1: (..., G, kb, p) uint8
    plan: AnyPlan
    members: tuple  # sibling keys in call-site order, e.g. ("wk", "wv")
    b: Any = None  # None | (..., G, p) | tuple[(..., p) | None, ...]
    # Weight family: ONE scalar dequant scale shared by every member (the
    # group leaf is a single stacked array, quantized as one); None for
    # full-width tables.  TL1 family: per-member ternary scales, stacked
    # ``(..., G)`` (each member's absmean fit is its own).
    scale: Any = None

    def tree_flatten_with_keys(self):
        return (
            (
                (jax.tree_util.GetAttrKey("tables"), self.tables),
                (jax.tree_util.GetAttrKey("b"), self.b),
                (jax.tree_util.GetAttrKey("scale"), self.scale),
            ),
            (self.plan, self.members),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        plan, members = aux
        tables, b, scale = children
        return cls(tables, plan, members, b, scale)

    def member_bias(self, g: int):
        if self.b is None:
            return None
        if isinstance(self.b, tuple):
            return self.b[g]
        return self.b[..., g, :]


@dataclasses.dataclass(frozen=True)
class ConvertReport:
    converted: int
    skipped: int
    weight_bytes: int
    table_bytes: int
    grouped: int = 0  # number of LUTGroup nodes emitted


def _is_linear_node(node: Any) -> bool:
    # 2-D = plain linear; 3-D = scan-stacked (L, q, p) — both convertible
    return (
        isinstance(node, dict)
        and "w" in node
        and hasattr(node["w"], "ndim")
        and node["w"].ndim in (2, 3)
        and set(node) <= {"w", "b"}
    )


def _is_expert_stack(node: Any) -> bool:
    return (
        isinstance(node, dict)
        and {"w_gate", "w_up", "w_down", "router"} <= set(node)
        and hasattr(node["w_gate"], "ndim")
        and node["w_gate"].ndim in (3, 4)
    )


def sibling_groups(node: dict) -> list[tuple[str, ...]]:
    """Fusable sibling sets present in ``node``: for each candidate key set
    in :data:`FUSABLE_SIBLINGS`, the same-``w``-shape classes with >= 2
    members (shape equality includes any leading scan/layer dims).  Shared
    with the planner so grouping decisions never drift between the two."""
    out: list[tuple[str, ...]] = []
    for base in FUSABLE_SIBLINGS:
        present = [n for n in base if n in node and _is_linear_node(node[n])]
        by_shape: dict[tuple, list[str]] = {}
        for n in present:
            by_shape.setdefault(tuple(node[n]["w"].shape), []).append(n)
        for members in by_shape.values():
            if len(members) > 1:
                out.append(tuple(members))
    return out


def expert_sibling_groups(node: dict) -> list[tuple[str, ...]]:
    """Fusable sibling sets among the RAW expert-stack weights of ``node``
    (an ``_is_expert_stack`` dict): same-shape classes of the candidate key
    sets, shape equality including the leading layer/expert dims — the
    expert-stack analogue of :func:`sibling_groups` (members are bare
    ``(..., E, q, p)`` arrays, not ``{"w": ...}`` linear nodes).  Shared
    with the planner so grouping decisions never drift."""
    out: list[tuple[str, ...]] = []
    for base in FUSABLE_SIBLINGS:
        present = [
            n for n in base if n in EXPERT_WEIGHT_KEYS and hasattr(node.get(n), "ndim")
        ]
        by_shape: dict[tuple, list[str]] = {}
        for n in present:
            by_shape.setdefault(tuple(node[n].shape), []).append(n)
        for members in by_shape.values():
            if len(members) > 1:
                out.append(tuple(members))
    return out


def group_key(members: tuple) -> str:
    """Tree key a :class:`LUTGroup` is stored under (e.g. ``"wk+wv"``)."""
    return "+".join(members)


def _build_tables(w, plan: LUTPlan, dtype):
    """build_luts vmapped over any leading (layer/expert) dims."""

    def fn(m):
        return build_luts(m.astype(jnp.float32), plan)

    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w).astype(dtype)


def _build_tl1(w):
    """build_tl1_tables vmapped over any leading (layer/expert) dims.

    Returns ``(packed (..., kb, p) uint8, scale (...) f32)`` — one ternary
    scale per weight matrix, shaped like the leading dims."""

    fn = build_tl1_tables
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w.astype(jnp.float32))


def convert_params(
    params: dict,
    chunk_size: int = 1,
    min_features: int = 1,
    predicate: Callable[[tuple, dict], bool] | None = None,
    table_dtype=jnp.float32,
    convert_experts: bool = False,
    signed: bool = True,  # LM activations are signed; paper models may use False
    plan: Optional[ModelPlan] = None,
    group_siblings: bool = True,
) -> tuple[dict, ConvertReport]:
    """Returns (converted tree, report).  ``predicate(path, node)`` can veto
    individual layers (default: convert everything eligible).

    With ``plan`` (a :class:`repro.core.planner.ModelPlan`, e.g. from
    ``plan_model``) each layer uses its *own* plan, looked up by tree path;
    layers absent from the plan are skipped — but a plan entry that the
    converter never consumes (a path the tree lacks, the predicate vetoes,
    or an expert entry without ``convert_experts=True``) **raises**, so
    planner/converter eligibility can never silently disagree.

    ``group_siblings=True`` (the default) emits fusable sibling projections
    as one pre-stacked :class:`LUTGroup` per group: always under the
    uniform plan, and exactly the groups ``plan.groups`` declares under a
    planned conversion (``plan_model`` never splits a group across plans).
    Pass ``group_siblings=False`` for the flat per-projection layout.
    """
    stats = {"converted": 0, "skipped": 0, "w_bytes": 0, "t_bytes": 0, "groups": 0}
    fmt = Float16Format(signed=signed)
    used_plan_keys: set[str] = set()
    declared_groups = (
        {frozenset(g) for g in plan.groups} if plan is not None else None
    )

    def member_plan(path: tuple, node: dict) -> Optional[AnyPlan]:
        """The plan this linear converts under, or None to leave it dense."""
        w = node["w"]
        q, p = w.shape[-2:]
        if q < min_features or (predicate and not predicate(path, node)):
            return None
        if plan is None:
            return LUTPlan(q, p, chunk_size, fmt, mode="bitplane")
        layer_plan = plan.layers.get(path_key(path))
        if layer_plan is None:
            return None
        if (layer_plan.in_features, layer_plan.out_features) != (q, p):
            raise ValueError(
                f"plan for {path_key(path)} is "
                f"{layer_plan.in_features}x{layer_plan.out_features}, "
                f"layer is {q}x{p}"
            )
        used_plan_keys.add(path_key(path))
        return layer_plan

    def finalize_tables(tables, layer_plan: LUTPlan, trailing: int):
        """(stored tables, scale): narrow-quantize when the plan asks for it.

        ``trailing`` = dims of one dispatched table set; leading (scan)
        dims keep per-set scales so the leaf stays scan-sliceable."""
        if layer_plan.table_format is None:
            return tables.astype(table_dtype), None
        return quantize_tables(tables, layer_plan.table_format, trailing)

    def convert_one(node: dict, layer_plan: AnyPlan, expert: bool = False) -> LUTLinear:
        w = node["w"]
        if isinstance(layer_plan, TL1Plan):
            tables, scale = _build_tl1(w)
        else:
            tables, scale = finalize_tables(
                _build_tables(w, layer_plan, jnp.float32), layer_plan, 3 + expert
            )
        stats["converted"] += 1
        stats["w_bytes"] += w.size * w.dtype.itemsize
        stats["t_bytes"] += tables.size * tables.dtype.itemsize
        return LUTLinear(tables=tables, plan=layer_plan, b=node.get("b"), scale=scale)

    def convert_group(
        path: tuple, node: dict, members: tuple, expert: bool = False
    ) -> Optional[LUTGroup]:
        """One LUTGroup for ``members``, or None when they can't share a
        plan (then they convert individually, like before grouping)."""
        key_tuple = frozenset(path_key(path + (m,)) for m in members)
        declared = declared_groups is not None and key_tuple in declared_groups
        if declared_groups is not None and not declared:
            return None  # planned conversion: only plan-declared groups fuse
        plans = [member_plan(path + (m,), node[m]) for m in members]
        if any(p is None for p in plans):
            if declared:
                raise ValueError(
                    f"plan declares group {group_key(members)} at "
                    f"{path_key(path)} but not every member is convertible"
                )
            return None
        if any(p != plans[0] for p in plans[1:]):
            # a hand-edited plan split the group; plan_model never does
            raise ValueError(
                f"group {group_key(members)} at {path_key(path)} has "
                f"mismatched member plans — grouped siblings must share one"
            )
        if isinstance(plans[0], TL1Plan):
            built = [_build_tl1(node[m]["w"]) for m in members]
            # stack G just before the packed-chunk axis: (..., G, kb, p);
            # ternary scales are per member, stacked to (..., G)
            tables = jnp.stack([t for t, _ in built], axis=built[0][0].ndim - 2)
            scale = jnp.stack([s for _, s in built], axis=-1)
        else:
            member_tables = [
                _build_tables(node[m]["w"], plans[0], jnp.float32) for m in members
            ]
            # quantize the STACKED leaf as one, so the whole group shares one
            # dequant scale (the group executes as a single fused dispatch)
            tables, scale = finalize_tables(
                jnp.stack(member_tables, axis=member_tables[0].ndim - 3),
                plans[0],
                4 + expert,
            )
        stats["converted"] += len(members)
        for m in members:
            w = node[m]["w"]
            stats["w_bytes"] += w.size * w.dtype.itemsize
        stats["t_bytes"] += tables.size * tables.dtype.itemsize
        biases = [node[m].get("b") for m in members]
        if all(b is not None for b in biases):
            b = jnp.stack(biases, axis=biases[0].ndim - 1)
        elif any(b is not None for b in biases):
            b = tuple(biases)  # mixed-bias group: per-member leaves
        else:
            b = None
        stats["groups"] += 1
        return LUTGroup(
            tables=tables, plan=plans[0], members=members, b=b, scale=scale
        )

    def convert_expert_member(path: tuple, key: str, w3) -> Any:
        # same eligibility/plan rules as plain linears (member_plan), so
        # planner and converter can never disagree on expert stacks
        layer_plan = member_plan(path + (key,), {"w": w3})
        if layer_plan is None:
            stats["skipped"] += 1
            return w3
        return convert_one({"w": w3}, layer_plan, expert=True)

    def walk(path: tuple, node: Any):
        if _is_linear_node(node):
            layer_plan = member_plan(path, node)
            if layer_plan is None:
                stats["skipped"] += 1
                return node
            return convert_one(node, layer_plan)
        if not isinstance(node, dict):
            return node
        if convert_experts and _is_expert_stack(node):
            # same grouping machinery as dense siblings: wrap the raw
            # (..., E, q, p) stacks as linear nodes so convert_group's
            # plan/eligibility checks apply unchanged; the stacked leaf is
            # (..., E, G, k, entries, p) — lut_affine_experts' layout
            egrouped: dict[str, LUTGroup] = {}
            econsumed: set[str] = set()
            if group_siblings:
                wrapped = {
                    k: {"w": v} for k, v in node.items() if k in EXPERT_WEIGHT_KEYS
                }
                for members in expert_sibling_groups(node):
                    g = convert_group(path, wrapped, members, expert=True)
                    if g is not None:
                        egrouped[group_key(members)] = g
                        econsumed |= set(members)
            eout: dict[str, Any] = {}
            for k, v in node.items():
                if k in econsumed:
                    gk = next(gk for gk, g in egrouped.items() if k in g.members)
                    if gk not in eout:
                        eout[gk] = egrouped[gk]
                elif k in EXPERT_WEIGHT_KEYS:
                    eout[k] = convert_expert_member(path, k, v)
                else:
                    eout[k] = walk(path + (k,), v)
            return eout
        grouped: dict[str, LUTGroup] = {}
        consumed: set[str] = set()
        if group_siblings:
            for members in sibling_groups(node):
                g = convert_group(path, node, members)
                if g is not None:
                    grouped[group_key(members)] = g
                    consumed |= set(members)
        out: dict[str, Any] = {}
        for k, v in node.items():
            if k in consumed:
                gk = next(gk for gk, g in grouped.items() if k in g.members)
                if gk not in out:
                    out[gk] = grouped[gk]
                continue
            out[k] = walk(path + (k,), v)
        return out

    out = walk((), params)
    if plan is not None:
        unused = sorted(set(plan.layers) - used_plan_keys)
        if unused:
            raise ValueError(
                "plan entries the converter never consumed (planner/converter "
                f"eligibility mismatch — check predicate/min_features/"
                f"convert_experts): {unused}"
            )
    report = ConvertReport(
        stats["converted"],
        stats["skipped"],
        stats["w_bytes"],
        stats["t_bytes"],
        stats["groups"],
    )
    return out, report


def conversion_summary(report: ConvertReport) -> str:
    ratio = report.table_bytes / max(report.weight_bytes, 1)
    return (
        f"converted {report.converted} linears ({report.skipped} skipped, "
        f"{report.grouped} pre-stacked groups): "
        f"{report.weight_bytes / 2**20:.1f} MiB weights -> "
        f"{report.table_bytes / 2**20:.1f} MiB tables ({ratio:.0f}x)"
    )
