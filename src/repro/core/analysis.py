"""Analytic reproduction of the paper's tables (memory/op accounting).

Every figure in the paper that is *derivable* (Figs. 5, 7, 8 and the inline
numbers) is reproduced here exactly from :class:`LUTPlan` accounting; the
benchmark harness prints them and ``tests/test_analysis.py`` asserts the
paper's own stated values.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.lut import LUTPlan
from repro.core.planner import enumerate_plans
from repro.core.quantize import FixedPointFormat, Float16Format

KiB = 2**10
MiB = 2**20
GiB = 2**30


@dataclasses.dataclass(frozen=True)
class LayerShape:
    in_features: int
    out_features: int


# The paper's three example networks (dense/affine layers only — ReLU,
# pooling and argmax are comparison-free in both implementations).
LINEAR_CLASSIFIER = (LayerShape(784, 10),)
MLP = (LayerShape(784, 1024), LayerShape(1024, 512), LayerShape(512, 10))
# LeNet-ish CNN from the TF tutorial, dense view of each layer:
#   conv1 5x5x1->32 (28x28 'same'), conv2 5x5x32->64 (14x14), fc 3136->1024,
#   fc 1024->10.  Conv layers use the paper's shared-LUT-across-positions
#   trick, so their *table* cost is position-independent while their op
#   count scales with positions.
CNN_DENSE = (LayerShape(3136, 1024), LayerShape(1024, 10))
CNN_CONVS = (
    # (patch_size q, out_channels p, spatial positions)
    (25, 32, 28 * 28),
    (25 * 32, 64, 14 * 14),
)


def network_cost(
    layers: Sequence[LayerShape], fmt, chunk_size: int, mode: str = "bitplane"
):
    """Aggregate (tables, bytes, evals, shift-adds) over dense layers."""
    tables = bytes_ = evals = adds = 0
    for shape in layers:
        plan = LUTPlan(
            shape.in_features, shape.out_features, chunk_size, fmt, mode=mode
        )
        tables += plan.num_chunks
        bytes_ += plan.total_lut_bytes
        evals += plan.lut_evaluations
        adds += plan.shift_add_ops
    return dict(tables=tables, bytes=bytes_, evals=evals, shift_adds=adds)


def conv_layer_cost(patch: int, out_ch: int, positions: int, fmt, chunk_size: int):
    """Paper §Convolutional layers: one table set shared across positions.

    Table size is that of a single patch's plan; evaluations/adds multiply by
    the number of output positions (spatial shift-and-add).
    """
    plan = LUTPlan(patch, out_ch, chunk_size, fmt)
    return dict(
        tables=plan.num_chunks,
        bytes=plan.total_lut_bytes,
        evals=plan.lut_evaluations * positions,
        shift_adds=plan.shift_add_ops * positions + out_ch * (positions - 1),
    )


def paper_claims() -> dict:
    """Every inline number in the paper, recomputed from our formulas."""
    fp3 = FixedPointFormat(3, 3)  # 3-bit input pixels in [0, 1)
    f16 = Float16Format()

    lin14 = LUTPlan(784, 10, 14, fp3)  # the "56 LUTs" configuration
    lin1 = LUTPlan(784, 10, 1, fp3)  # the "784 LUTs" configuration

    mlp_bp = network_cost(MLP, f16, 1, mode="bitplane")
    mlp_full = network_cost(MLP, f16, 1, mode="full")

    cnn_dense = network_cost(CNN_DENSE, f16, 1, mode="bitplane")
    cnn_convs = [conv_layer_cost(q, p, pos, f16, 1) for q, p, pos in CNN_CONVS]
    cnn_total_bytes = cnn_dense["bytes"] + sum(c["bytes"] for c in cnn_convs)
    cnn_total_adds = cnn_dense["shift_adds"] + sum(c["shift_adds"] for c in cnn_convs)

    return {
        # paper: "56 LUTs ... 17.5 Mebibytes, 168 LUT evaluations and 1650
        # shift-and-add operations"
        "linear_m14": dict(
            tables=lin14.num_chunks,
            mib=lin14.total_lut_bytes / MiB,
            evals=lin14.lut_evaluations,
            shift_adds=lin14.shift_add_ops,
        ),
        # paper: "784 LUTs totaling about 30.6 Kibibytes ... 23520 shift-adds"
        "linear_m1": dict(
            tables=lin1.num_chunks,
            kib=lin1.total_lut_bytes / KiB,
            shift_adds=lin1.shift_add_ops,
        ),
        # paper: "2320 LUTs with a combined size of 162.6 Mebibytes and
        # 14652918 shift-and-add operations"
        "mlp_bitplane": dict(
            tables=mlp_bp["tables"],
            mib=mlp_bp["bytes"] / MiB,
            shift_adds=mlp_bp["shift_adds"],
        ),
        # paper: "2320 LUTs ... 1330678 addition operations" (full 16-bit
        # indexing; the paper's 32.7 GiB does not back out of its own size
        # formula — see EXPERIMENTS.md §Repro for the discrepancy note)
        "mlp_full": dict(
            tables=mlp_full["tables"],
            gib=mlp_full["bytes"] / GiB,
            adds=mlp_full["shift_adds"],
        ),
        # paper: "total LUT size is 400 Mebibytes ... 37.4M shift+add"
        "cnn_bitplane": dict(mib=cnn_total_bytes / MiB, shift_adds=cnn_total_adds),
        # reference model op counts quoted by the paper
        "linear_ref_madds": 784 * 10,
        "mlp_ref_madds": 784 * 1024 + 1024 * 512 + 512 * 10,
    }


def figure_curve(layers: Sequence[LayerShape], fmt, modes=("bitplane", "full")):
    """Fig. 5/7/8-style curve: total size vs ops across chunk sizes."""
    rows = []
    # chunk sizes are applied uniformly across layers, as in the paper
    probe = enumerate_plans(layers[0].in_features, layers[0].out_features, fmt, modes)
    seen = sorted({(p.plan.mode, p.plan.chunk_size) for p in probe})
    for mode, m in seen:
        try:
            cost = network_cost(layers, fmt, m, mode=mode)
        except ValueError:
            continue
        rows.append(dict(mode=mode, chunk=m, **cost))
    return sorted(rows, key=lambda r: r["bytes"])
