"""TableNet LUT construction and (reference) application.

Implements the paper's replacement of an affine map ``y = W x + b`` with
look-up tables:

* ``mode="bitplane"`` (fixed point or binary16): the input's bits are viewed
  as ``n`` bitplanes; the *same* ``k`` tables are reused across planes and
  the plane results are shift-and-added (paper §Fixed point / §Floating
  point).  Table ``c`` maps the chunk-``c`` bit pattern (for binary16: one
  mantissa bit **plus the full 5-bit exponent** per element, paper Fig. 1) to
  the partial output vector ``W_chunk · alpha``.
* ``mode="full"`` (fixed point): each table is indexed by the *totality* of
  the chunk's bits (``m * r_I`` index bits) — fewest ops, biggest tables.
* ``mode="bitplane_shift"`` (binary16, chunk 1): the exponent is factored
  OUT of the table and applied at accumulate time as a per-element scale
  ``sigma(e) = 2**(e-25)`` — a barrel shift in hardware, so the path stays
  multiplier-free.  Tables index only ``[sign?][mantissa slice]`` and
  collapse from ``2**(r+6)`` to ``2**(r+1)`` entries per chunk (the
  sigma-laden entries repeat 32x across exponent values); the packed code
  carries the exponent in its high bits.  This is the cache-resident
  variant: a whole model's tables fit in L2.

Signed fixed point follows the paper's MSB trick: the MSB plane passes
through the *same* tables and is subtracted after a left shift — realised
here as a negative final plane scale (exactly equivalent).

The bias is added once at the end rather than as ``b/k`` per table; this is
algebraically identical and avoids ``k-1`` redundant additions of ``b/k``.

Everything here is the pure-jnp *oracle*; the Pallas kernels in
``repro.kernels`` implement the same contract and are tested against it.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import FixedPointFormat, Float16Format

Format = Union[FixedPointFormat, Float16Format]


@dataclasses.dataclass(frozen=True)
class LUTPlan:
    """How one affine layer (q -> p) is mapped onto LUTs."""

    in_features: int  # q
    out_features: int  # p
    chunk_size: int  # m: input elements per table
    fmt: Format
    mode: str = "bitplane"  # "bitplane" | "full"
    out_bits: int = 16  # r_O, for size accounting only (compute is fp32)
    # Storage format of the table entries: None keeps the converter's
    # table_dtype (accounted at out_bits); "i8"/"i16" store integer tables
    # with one power-of-2 dequant scale per table set, folded into the
    # per-plane accumulate (a shift, not a multiply).
    table_format: str | None = None
    # Autotuned Pallas tile sizes (block_b, block_p, block_k), persisted
    # through ModelPlan JSON so tuned plans ride checkpoints.  None falls
    # back to the static heuristic in kernels.lut_affine.
    blocks: tuple[int, int, int] | None = None
    # Accumulator contract: the dtype the kernels accumulate partial sums
    # in (weight-family kernels always widen gathered rows to fp32) and
    # the statically proved worst-case |accumulator| for this plan
    # (``repro.audit.ranges.layer_range_cert``, stamped by ``plan_model``
    # and riding checkpoints like ``blocks``).  ``max_abs_acc`` is derived
    # metadata, so like a cache it is excluded from equality — two plans
    # that differ only in the stamp describe the same layer mapping.
    acc_dtype: str = "float32"
    max_abs_acc: float | None = dataclasses.field(default=None, compare=False)

    # The table-family axis: "weight" = tables built from weights at convert
    # time, indexed by activation codes (every mode above).  The second
    # family, "tl1" (repro.core.lut_tl1.TL1Plan), inverts the layout.
    table_family = "weight"

    def __post_init__(self):
        if self.mode not in ("bitplane", "full", "bitplane_shift"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.mode == "full" and isinstance(self.fmt, Float16Format):
            if self.chunk_size != 1:
                raise ValueError("full-bits float LUTs only support chunk_size=1")
        if self.mode == "bitplane_shift":
            if not isinstance(self.fmt, Float16Format):
                raise ValueError("bitplane_shift requires Float16Format")
            if self.chunk_size != 1:
                # >1 element per index would need one exponent shift per
                # element inside a single gathered row — not representable.
                raise ValueError("bitplane_shift only supports chunk_size=1")
        if self.table_format not in (None, "i8", "i16"):
            raise ValueError(f"unknown table_format {self.table_format!r}")
        if self.blocks is not None:
            object.__setattr__(self, "blocks", tuple(int(v) for v in self.blocks))
            if len(self.blocks) != 3 or any(v <= 0 for v in self.blocks):
                raise ValueError(f"blocks must be 3 positive ints, got {self.blocks}")
        if self.acc_dtype not in ("int16", "int32", "float32"):
            raise ValueError(f"unknown acc_dtype {self.acc_dtype!r}")
        if self.max_abs_acc is not None:
            object.__setattr__(self, "max_abs_acc", float(self.max_abs_acc))
            if self.max_abs_acc < 0:
                raise ValueError(f"max_abs_acc must be >= 0, got {self.max_abs_acc}")
        if self.index_bits > 24:
            raise ValueError(
                f"LUT index width {self.index_bits} bits is impractically large"
            )

    # -- derived sizes --------------------------------------------------------
    @property
    def num_chunks(self) -> int:  # k
        return -(-self.in_features // self.chunk_size)

    @property
    def padded_in(self) -> int:
        return self.num_chunks * self.chunk_size

    @property
    def fields_per_element(self) -> int:
        """Index bits contributed by one input element."""
        if isinstance(self.fmt, Float16Format):
            if self.mode == "full":
                # all 16 bits, minus the sign bit (always 0 post-ReLU).
                return 15
            if self.mode == "bitplane_shift":
                # exponent lives outside the index (applied as a shift).
                return self.fmt.mantissa_radix + (1 if self.fmt.signed else 0)
            return self.fmt.fields_per_element  # mantissa slice + 5 exp bits
        return 1 if self.mode == "bitplane" else self.fmt.total_bits

    @property
    def index_bits(self) -> int:
        return self.chunk_size * self.fields_per_element

    @property
    def num_entries(self) -> int:
        return 2**self.index_bits

    @property
    def num_planes(self) -> int:
        if self.mode == "full":
            return 1
        return self.fmt.num_planes

    # -- paper's cost accounting (validated against the paper's own numbers) --
    @property
    def lut_evaluations(self) -> int:
        return self.num_planes * self.num_chunks

    @property
    def shift_add_ops(self) -> int:
        """p-element adds: p * (n*k - 1)  — reproduces the paper's 14,652,918
        for the MLP and 1,330,678 for the full-bits variant exactly."""
        return self.out_features * (self.lut_evaluations - 1)

    @property
    def storage_bits(self) -> int:
        """Bits per stored table entry (``out_bits`` unless a narrow
        ``table_format`` overrides it)."""
        if self.table_format == "i8":
            return 8
        if self.table_format == "i16":
            return 16
        return self.out_bits

    @property
    def total_lut_bits(self) -> int:
        per_entry = self.out_features * self.storage_bits
        return self.num_chunks * self.num_entries * per_entry

    @property
    def total_lut_bytes(self) -> int:
        return self.total_lut_bits // 8


# ---------------------------------------------------------------------------
# Table construction
# ---------------------------------------------------------------------------


def _chunked_weights(W: jax.Array, plan: LUTPlan) -> jax.Array:
    """(q, p) -> (k, m, p), zero-padding the ragged tail chunk (exact: the
    padded elements always present a 0 bit pattern)."""
    q, p = W.shape
    assert q == plan.in_features and p == plan.out_features
    pad = plan.padded_in - q
    Wp = jnp.pad(W, ((0, pad), (0, 0)))
    return Wp.reshape(plan.num_chunks, plan.chunk_size, p)


def _fixed_full_coeffs(plan: LUTPlan) -> np.ndarray:
    """(entries, m) dequantised value of each element slot for every index."""
    fmt: FixedPointFormat = plan.fmt  # type: ignore[assignment]
    r = fmt.total_bits
    idx = np.arange(plan.num_entries, dtype=np.int64)
    slots = np.arange(plan.chunk_size)
    codes = (idx[:, None] >> (slots[None, :] * r)) & (2**r - 1)
    if fmt.signed:
        codes = codes - (codes >= 2 ** (r - 1)) * 2**r
    return codes.astype(np.float64) * fmt.scale


def _float_bitplane_coeffs(plan: LUTPlan) -> np.ndarray:
    """(entries, m): (+/-) mantissa_slice * sigma(exp) per element slot (paper
    Fig. 1; field layout [sign?][radix-bit mantissa slice][5-bit exponent])."""
    fmt: Float16Format = plan.fmt  # type: ignore[assignment]
    f = fmt.fields_per_element  # radix + 5 unsigned / radix + 6 signed
    r = fmt.mantissa_radix
    idx = np.arange(plan.num_entries, dtype=np.int64)
    slots = np.arange(plan.chunk_size)
    fields = (idx[:, None] >> (slots[None, :] * f)) & (2**f - 1)
    slices = (fields >> fmt.exp_bits) & (2**r - 1)
    exps = fields & (2**fmt.exp_bits - 1)
    sigma = 2.0 ** (np.maximum(exps, 1).astype(np.float64) - 25.0)
    coeff = slices.astype(np.float64) * sigma
    if fmt.signed:
        sign = fields >> (fmt.exp_bits + r)
        coeff = coeff * (1.0 - 2.0 * sign)
    return coeff


def _float_shift_coeffs(plan: LUTPlan) -> np.ndarray:
    """(entries, 1): (+/-) mantissa_slice per index — NO sigma baked in.

    The exponent scale is applied at accumulate time (``bitplane_shift``), so
    entry values span only ``[-(2**r - 1), 2**r - 1]`` — which is what makes
    narrow integer storage of these tables accuracy-safe."""
    fmt: Float16Format = plan.fmt  # type: ignore[assignment]
    r = fmt.mantissa_radix
    idx = np.arange(plan.num_entries, dtype=np.int64)
    coeff = (idx & (2**r - 1)).astype(np.float64)
    if fmt.signed:
        coeff = coeff * (1.0 - 2.0 * (idx >> r))
    return coeff[:, None]


def _float_full_coeffs(plan: LUTPlan) -> np.ndarray:
    """(2**15, 1): value of each non-negative binary16 bit pattern."""
    idx = np.arange(plan.num_entries, dtype=np.uint16)
    vals = idx.view(np.float16).astype(np.float64)
    return vals[:, None]


def build_luts(W: jax.Array, plan: LUTPlan) -> jax.Array:
    """Materialise tables of shape ``(k, entries, p)`` in fp32.

    Entry ``T[c, e, :]`` holds ``sum_i coeff_i(e) * W[chunk_c[i], :]`` — the
    exact partial result the paper stores.  For bitplane mode the per-plane
    scale (2**j, fixed-point 2**-f, signed MSB sign) lives in
    :func:`plane_scales` and is applied at accumulation time, which is what
    lets one table serve every plane.
    """
    if isinstance(plan.fmt, Float16Format):
        if plan.mode == "bitplane":
            coeffs = _float_bitplane_coeffs(plan)
        elif plan.mode == "bitplane_shift":
            coeffs = _float_shift_coeffs(plan)
        else:
            coeffs = _float_full_coeffs(plan)
    else:
        if plan.mode == "bitplane":
            # pattern bit i contributes W row as-is; scale handled per plane.
            idx = np.arange(plan.num_entries, dtype=np.int64)
            slots = np.arange(plan.chunk_size)
            coeffs = ((idx[:, None] >> slots[None, :]) & 1).astype(np.float64)
        else:
            coeffs = _fixed_full_coeffs(plan)
    Wc = _chunked_weights(W, plan)  # (k, m, p)
    return jnp.einsum(
        "em,kmp->kep", jnp.asarray(coeffs, jnp.float32), Wc.astype(jnp.float32)
    )


def plane_scales(plan: LUTPlan) -> np.ndarray:
    """(num_planes,) multipliers applied to per-plane table sums."""
    if plan.mode == "full":
        return np.ones((1,), np.float64)
    return plan.fmt.plane_scales()


# ---------------------------------------------------------------------------
# Input packing: float/ints -> LUT index codes
# ---------------------------------------------------------------------------


def _pack_fields(fields: jax.Array, plan: LUTPlan) -> jax.Array:
    """(..., q_padded) per-element field ints -> (..., k) chunk indices."""
    f = plan.fields_per_element
    chunked = fields.reshape(fields.shape[:-1] + (plan.num_chunks, plan.chunk_size))
    shifts = (jnp.arange(plan.chunk_size, dtype=jnp.int32) * f).reshape(
        (1,) * (chunked.ndim - 1) + (-1,)
    )
    return jnp.sum(chunked << shifts, axis=-1).astype(jnp.int32)


def pack_codes(x: jax.Array, plan: LUTPlan) -> jax.Array:
    """Quantise ``x`` (..., q) and emit LUT indices of shape (..., n, k).

    This is the bit-partitioning step the paper assumes custom routing
    hardware for; the Pallas ``bitplane_pack`` kernel implements the same
    contract on-chip.
    """
    pad = plan.padded_in - plan.in_features
    if isinstance(plan.fmt, Float16Format):
        h = plan.fmt.quantize(x)
        if pad:
            h = jnp.pad(h, [(0, 0)] * (h.ndim - 1) + [(0, pad)])
        if plan.mode == "full":
            u = jax.lax.bitcast_convert_type(h, jnp.uint16).astype(jnp.int32)
            return u[..., None, :]  # (..., 1, k) with k == q
        exp, planes = plan.fmt.decompose(h)  # (...,q), (n,...,q)
        if plan.mode == "bitplane_shift":
            r = plan.fmt.mantissa_radix
            fields = planes
            if plan.fmt.signed:
                fields = fields + (plan.fmt.sign_bits(h) << r)[None]
            # exponent rides in the high bits: gather with
            # ``code & (entries-1)``, shift with ``code >> index_bits``.
            codes = fields + (exp << plan.index_bits)[None]
            return jnp.moveaxis(codes.astype(jnp.int32), 0, -2)  # (..., n, k)
        fields = (planes << plan.fmt.exp_bits) + exp[None]
        if plan.fmt.signed:
            sign = plan.fmt.sign_bits(h)
            shift = plan.fmt.exp_bits + plan.fmt.mantissa_radix
            fields = fields + (sign << shift)[None]
        codes = _pack_fields(fields, plan)  # (n, ..., k)
        return jnp.moveaxis(codes, 0, -2)  # (..., n, k)
    fmt: FixedPointFormat = plan.fmt  # type: ignore[assignment]
    c = fmt.quantize(x)
    if pad:
        c = jnp.pad(c, [(0, 0)] * (c.ndim - 1) + [(0, pad)])
    if plan.mode == "full":
        u = fmt.to_unsigned_bits(c)
        return _pack_fields(u, plan)[..., None, :]
    bits = fmt.bitplanes(c)  # (n, ..., q)
    codes = _pack_fields(bits, plan)  # (n, ..., k)
    return jnp.moveaxis(codes, 0, -2)


# ---------------------------------------------------------------------------
# Reference application (the jnp oracle for the Pallas kernel)
# ---------------------------------------------------------------------------


def table_scale(
    tables: jax.Array, table_format: str, trailing: int | None = None
) -> jax.Array:
    """Power-of-2 dequant scale for quantizing ``tables`` to ``table_format``.

    The scale is ``2**ceil(log2(maxabs / qmax))`` so folding it into the
    per-plane accumulate stays a shift, never a multiply.  ``trailing`` is
    the number of trailing dims forming ONE dispatched table set (3 for a
    ``(k, E, p)`` linear, +1 for a group stack, +1 for an expert stack):
    those dims share a scalar, while leading scan dims — sliced off by the
    layer scan before any dispatch sees them — get their own entry, keeping
    the leaf sliceable alongside its tables.  ``None`` = one scalar for the
    whole leaf.  Safe under tracing (``eval_shape`` / ``vmap``): pure jnp,
    no host round-trip.
    """
    qmax = {"i8": 127.0, "i16": 32767.0}[table_format]
    t = jnp.abs(tables.astype(jnp.float32))
    if trailing is None or trailing >= tables.ndim:
        maxabs = jnp.max(t)
    else:
        maxabs = jnp.max(t, axis=tuple(range(tables.ndim - trailing, tables.ndim)))
    maxabs = jnp.maximum(maxabs, jnp.finfo(jnp.float32).tiny)
    return jnp.exp2(jnp.ceil(jnp.log2(maxabs / qmax)))


def quantize_tables(
    tables: jax.Array, table_format: str, trailing: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """fp32 tables -> (narrow integer tables, dequant scale).

    ``tables ≈ narrow.astype(f32) * scale`` with ``scale`` a power of two
    shared per table SET (see :func:`table_scale`; per-plane folding of a
    per-chunk scale would break the shared-table bitplane trick, so one
    scalar per dispatch it is).
    """
    dtype = {"i8": jnp.int8, "i16": jnp.int16}[table_format]
    qmax = {"i8": 127.0, "i16": 32767.0}[table_format]
    s = table_scale(tables, table_format, trailing)
    sb = s.reshape(s.shape + (1,) * (tables.ndim - s.ndim))
    q = jnp.clip(jnp.round(tables.astype(jnp.float32) / sb), -qmax, qmax)
    return q.astype(dtype), s


def apply_luts(
    tables: jax.Array,
    codes: jax.Array,
    plan: LUTPlan,
    bias: jax.Array | None = None,
    accum_dtype=jnp.float32,
    scales: jax.Array | None = None,
) -> jax.Array:
    """``(..., n, k)`` codes + ``(k, E, p)`` tables -> ``(..., p)``.

    out = sum_j scale_j * sum_c T[c, codes[..., j, c], :]  (+ bias)

    The two nested sums contract in ONE einsum over ``(n, k)`` — on CPU/GPU
    backends the decode step is dispatch-bound, and fusing the plane-sum
    with the scale-weighted reduce removes a full table-sized intermediate.
    ``scales`` overrides the plan's plane scales (callers fold narrow-table
    dequant scales in here; both are powers of two, so the fold is exact).

    ``bitplane_shift`` codes carry the element exponent in their high bits:
    the gather indexes ``code & (entries-1)`` and the accumulate weights
    each element by ``sigma(exp) = 2**(max(e,1)-25)`` — the barrel shift the
    mode's name refers to.
    """
    k = plan.num_chunks
    if scales is None:
        scales = jnp.asarray(plane_scales(plan), accum_dtype)
    scales = scales.astype(accum_dtype)
    if plan.mode == "bitplane_shift":
        idx = codes & (plan.num_entries - 1)
        exp = codes[..., 0, :] >> plan.index_bits  # same for every plane
        sig = jnp.exp2(jnp.maximum(exp, 1).astype(accum_dtype) - 25.0)  # (..., k)
        gathered = tables[jnp.arange(k), idx]  # (..., n, k, p)
        # scale rows by sigma BEFORE the plane contraction: XLA fuses the
        # broadcast multiply into the gather consumer, so this costs the
        # same as the sigma-free einsum (measured; the batched-weight
        # einsum "...nkp,...nk->...p" is ~5x slower on CPU).
        gathered = gathered.astype(accum_dtype) * sig[..., None, :, None]
        out = jnp.einsum("...nkp,n->...p", gathered, scales)
    else:
        gathered = tables[jnp.arange(k), codes]  # (..., n, k, p)
        out = jnp.einsum("...nkp,n->...p", gathered.astype(accum_dtype), scales)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def lut_affine_reference(
    x: jax.Array, W: jax.Array, b: jax.Array | None, plan: LUTPlan
) -> jax.Array:
    """End-to-end oracle: pack -> tables -> apply."""
    tables = build_luts(W, plan)
    codes = pack_codes(x, plan)
    return apply_luts(tables, codes, plan, bias=b)


def quantized_matmul_reference(
    x: jax.Array, W: jax.Array, b: jax.Array | None, plan: LUTPlan
) -> jax.Array:
    """What the LUT path must reproduce: matmul on the *quantised* input."""
    xq = plan.fmt.dequantize(plan.fmt.quantize(x))
    # zero-out the padded tail exactly as the LUT sees it
    out = xq.astype(jnp.float32) @ W.astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out
