"""TableNet LUT construction and (reference) application.

Implements the paper's replacement of an affine map ``y = W x + b`` with
look-up tables:

* ``mode="bitplane"`` (fixed point or binary16): the input's bits are viewed
  as ``n`` bitplanes; the *same* ``k`` tables are reused across planes and
  the plane results are shift-and-added (paper §Fixed point / §Floating
  point).  Table ``c`` maps the chunk-``c`` bit pattern (for binary16: one
  mantissa bit **plus the full 5-bit exponent** per element, paper Fig. 1) to
  the partial output vector ``W_chunk · alpha``.
* ``mode="full"`` (fixed point): each table is indexed by the *totality* of
  the chunk's bits (``m * r_I`` index bits) — fewest ops, biggest tables.

Signed fixed point follows the paper's MSB trick: the MSB plane passes
through the *same* tables and is subtracted after a left shift — realised
here as a negative final plane scale (exactly equivalent).

The bias is added once at the end rather than as ``b/k`` per table; this is
algebraically identical and avoids ``k-1`` redundant additions of ``b/k``.

Everything here is the pure-jnp *oracle*; the Pallas kernels in
``repro.kernels`` implement the same contract and are tested against it.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import FixedPointFormat, Float16Format

Format = Union[FixedPointFormat, Float16Format]


@dataclasses.dataclass(frozen=True)
class LUTPlan:
    """How one affine layer (q -> p) is mapped onto LUTs."""

    in_features: int  # q
    out_features: int  # p
    chunk_size: int  # m: input elements per table
    fmt: Format
    mode: str = "bitplane"  # "bitplane" | "full"
    out_bits: int = 16  # r_O, for size accounting only (compute is fp32)

    def __post_init__(self):
        if self.mode not in ("bitplane", "full"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.mode == "full" and isinstance(self.fmt, Float16Format):
            if self.chunk_size != 1:
                raise ValueError("full-bits float LUTs only support chunk_size=1")
        if self.index_bits > 24:
            raise ValueError(
                f"LUT index width {self.index_bits} bits is impractically large"
            )

    # -- derived sizes --------------------------------------------------------
    @property
    def num_chunks(self) -> int:  # k
        return -(-self.in_features // self.chunk_size)

    @property
    def padded_in(self) -> int:
        return self.num_chunks * self.chunk_size

    @property
    def fields_per_element(self) -> int:
        """Index bits contributed by one input element."""
        if isinstance(self.fmt, Float16Format):
            if self.mode == "full":
                # all 16 bits, minus the sign bit (always 0 post-ReLU).
                return 15
            return self.fmt.fields_per_element  # 1 mantissa bit + 5 exp bits
        return 1 if self.mode == "bitplane" else self.fmt.total_bits

    @property
    def index_bits(self) -> int:
        return self.chunk_size * self.fields_per_element

    @property
    def num_entries(self) -> int:
        return 2**self.index_bits

    @property
    def num_planes(self) -> int:
        if self.mode == "full":
            return 1
        return self.fmt.num_planes

    # -- paper's cost accounting (validated against the paper's own numbers) --
    @property
    def lut_evaluations(self) -> int:
        return self.num_planes * self.num_chunks

    @property
    def shift_add_ops(self) -> int:
        """p-element adds: p * (n*k - 1)  — reproduces the paper's 14,652,918
        for the MLP and 1,330,678 for the full-bits variant exactly."""
        return self.out_features * (self.lut_evaluations - 1)

    @property
    def total_lut_bits(self) -> int:
        return self.num_chunks * self.num_entries * self.out_features * self.out_bits

    @property
    def total_lut_bytes(self) -> int:
        return self.total_lut_bits // 8


# ---------------------------------------------------------------------------
# Table construction
# ---------------------------------------------------------------------------


def _chunked_weights(W: jax.Array, plan: LUTPlan) -> jax.Array:
    """(q, p) -> (k, m, p), zero-padding the ragged tail chunk (exact: the
    padded elements always present a 0 bit pattern)."""
    q, p = W.shape
    assert q == plan.in_features and p == plan.out_features
    pad = plan.padded_in - q
    Wp = jnp.pad(W, ((0, pad), (0, 0)))
    return Wp.reshape(plan.num_chunks, plan.chunk_size, p)


def _fixed_full_coeffs(plan: LUTPlan) -> np.ndarray:
    """(entries, m) dequantised value of each element slot for every index."""
    fmt: FixedPointFormat = plan.fmt  # type: ignore[assignment]
    r = fmt.total_bits
    idx = np.arange(plan.num_entries, dtype=np.int64)
    slots = np.arange(plan.chunk_size)
    codes = (idx[:, None] >> (slots[None, :] * r)) & (2**r - 1)
    if fmt.signed:
        codes = codes - (codes >= 2 ** (r - 1)) * 2**r
    return codes.astype(np.float64) * fmt.scale


def _float_bitplane_coeffs(plan: LUTPlan) -> np.ndarray:
    """(entries, m): (+/-) bit * sigma(exp) per element slot (paper Fig. 1;
    field layout [sign?][mantissa bit][5-bit exponent])."""
    fmt: Float16Format = plan.fmt  # type: ignore[assignment]
    f = fmt.fields_per_element  # 6 unsigned / 7 signed
    idx = np.arange(plan.num_entries, dtype=np.int64)
    slots = np.arange(plan.chunk_size)
    fields = (idx[:, None] >> (slots[None, :] * f)) & (2**f - 1)
    bits = (fields >> fmt.exp_bits) & 1
    exps = fields & (2**fmt.exp_bits - 1)
    sigma = 2.0 ** (np.maximum(exps, 1).astype(np.float64) - 25.0)
    coeff = bits.astype(np.float64) * sigma
    if fmt.signed:
        sign = fields >> (fmt.exp_bits + 1)
        coeff = coeff * (1.0 - 2.0 * sign)
    return coeff


def _float_full_coeffs(plan: LUTPlan) -> np.ndarray:
    """(2**15, 1): value of each non-negative binary16 bit pattern."""
    idx = np.arange(plan.num_entries, dtype=np.uint16)
    vals = idx.view(np.float16).astype(np.float64)
    return vals[:, None]


def build_luts(W: jax.Array, plan: LUTPlan) -> jax.Array:
    """Materialise tables of shape ``(k, entries, p)`` in fp32.

    Entry ``T[c, e, :]`` holds ``sum_i coeff_i(e) * W[chunk_c[i], :]`` — the
    exact partial result the paper stores.  For bitplane mode the per-plane
    scale (2**j, fixed-point 2**-f, signed MSB sign) lives in
    :func:`plane_scales` and is applied at accumulation time, which is what
    lets one table serve every plane.
    """
    if isinstance(plan.fmt, Float16Format):
        coeffs = (
            _float_bitplane_coeffs(plan)
            if plan.mode == "bitplane"
            else _float_full_coeffs(plan)
        )
    else:
        if plan.mode == "bitplane":
            # pattern bit i contributes W row as-is; scale handled per plane.
            idx = np.arange(plan.num_entries, dtype=np.int64)
            slots = np.arange(plan.chunk_size)
            coeffs = ((idx[:, None] >> slots[None, :]) & 1).astype(np.float64)
        else:
            coeffs = _fixed_full_coeffs(plan)
    Wc = _chunked_weights(W, plan)  # (k, m, p)
    return jnp.einsum(
        "em,kmp->kep", jnp.asarray(coeffs, jnp.float32), Wc.astype(jnp.float32)
    )


def plane_scales(plan: LUTPlan) -> np.ndarray:
    """(num_planes,) multipliers applied to per-plane table sums."""
    if plan.mode == "full":
        return np.ones((1,), np.float64)
    return plan.fmt.plane_scales()


# ---------------------------------------------------------------------------
# Input packing: float/ints -> LUT index codes
# ---------------------------------------------------------------------------


def _pack_fields(fields: jax.Array, plan: LUTPlan) -> jax.Array:
    """(..., q_padded) per-element field ints -> (..., k) chunk indices."""
    f = plan.fields_per_element
    chunked = fields.reshape(fields.shape[:-1] + (plan.num_chunks, plan.chunk_size))
    shifts = (jnp.arange(plan.chunk_size, dtype=jnp.int32) * f).reshape(
        (1,) * (chunked.ndim - 1) + (-1,)
    )
    return jnp.sum(chunked << shifts, axis=-1).astype(jnp.int32)


def pack_codes(x: jax.Array, plan: LUTPlan) -> jax.Array:
    """Quantise ``x`` (..., q) and emit LUT indices of shape (..., n, k).

    This is the bit-partitioning step the paper assumes custom routing
    hardware for; the Pallas ``bitplane_pack`` kernel implements the same
    contract on-chip.
    """
    pad = plan.padded_in - plan.in_features
    if isinstance(plan.fmt, Float16Format):
        h = plan.fmt.quantize(x)
        if pad:
            h = jnp.pad(h, [(0, 0)] * (h.ndim - 1) + [(0, pad)])
        if plan.mode == "full":
            u = jax.lax.bitcast_convert_type(h, jnp.uint16).astype(jnp.int32)
            return u[..., None, :]  # (..., 1, k) with k == q
        exp, planes = plan.fmt.decompose(h)  # (...,q), (n,...,q)
        fields = (planes << plan.fmt.exp_bits) + exp[None]
        if plan.fmt.signed:
            sign = plan.fmt.sign_bits(h)
            fields = fields + (sign << (plan.fmt.exp_bits + 1))[None]
        codes = _pack_fields(fields, plan)  # (n, ..., k)
        return jnp.moveaxis(codes, 0, -2)  # (..., n, k)
    fmt: FixedPointFormat = plan.fmt  # type: ignore[assignment]
    c = fmt.quantize(x)
    if pad:
        c = jnp.pad(c, [(0, 0)] * (c.ndim - 1) + [(0, pad)])
    if plan.mode == "full":
        u = fmt.to_unsigned_bits(c)
        return _pack_fields(u, plan)[..., None, :]
    bits = fmt.bitplanes(c)  # (n, ..., q)
    codes = _pack_fields(bits, plan)  # (n, ..., k)
    return jnp.moveaxis(codes, 0, -2)


# ---------------------------------------------------------------------------
# Reference application (the jnp oracle for the Pallas kernel)
# ---------------------------------------------------------------------------


def apply_luts(
    tables: jax.Array,
    codes: jax.Array,
    plan: LUTPlan,
    bias: jax.Array | None = None,
    accum_dtype=jnp.float32,
) -> jax.Array:
    """``(..., n, k)`` codes + ``(k, E, p)`` tables -> ``(..., p)``.

    out = sum_j scale_j * sum_c T[c, codes[..., j, c], :]  (+ bias)
    """
    k = plan.num_chunks
    gathered = tables[jnp.arange(k), codes]  # (..., n, k, p)
    per_plane = jnp.sum(gathered.astype(accum_dtype), axis=-2)  # (..., n, p)
    scales = jnp.asarray(plane_scales(plan), accum_dtype)
    out = jnp.einsum("...np,n->...p", per_plane, scales)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def lut_affine_reference(
    x: jax.Array, W: jax.Array, b: jax.Array | None, plan: LUTPlan
) -> jax.Array:
    """End-to-end oracle: pack -> tables -> apply."""
    tables = build_luts(W, plan)
    codes = pack_codes(x, plan)
    return apply_luts(tables, codes, plan, bias=b)


def quantized_matmul_reference(
    x: jax.Array, W: jax.Array, b: jax.Array | None, plan: LUTPlan
) -> jax.Array:
    """What the LUT path must reproduce: matmul on the *quantised* input."""
    xq = plan.fmt.dequantize(plan.fmt.quantize(x))
    # zero-out the padded tail exactly as the LUT sees it
    out = xq.astype(jnp.float32) @ W.astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out
