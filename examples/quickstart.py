"""Quickstart: train a small LM, convert it to TableNet LUTs, serve it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import get_config
from repro.core.convert import convert_params, conversion_summary
from repro.data.pipeline import lm_stream
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import count_params, init_params
from repro.serve.engine import generate
from repro.train.trainer import TrainConfig, Trainer


def main():
    cfg = get_config("granite_8b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    print(f"model: {cfg.name} (reduced) — {count_params(model_specs(cfg)):,} params")

    tc = TrainConfig(peak_lr=1e-2, warmup_steps=5, total_steps=40,
                     checkpoint_every=20, out_dir="/tmp/quickstart_run")
    data = lm_stream(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    log = Trainer(ctx, tc, params, data).run(40)
    print(f"trained 40 steps: loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")

    # paper's post-training conversion: every linear becomes LUTs
    trainer_params = Trainer(ctx, tc, params, data).params  # restored from ckpt
    lut_params, report = convert_params(trainer_params, chunk_size=1)
    print("TableNet conversion:", conversion_summary(report))

    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab_size)
    ref = generate(trainer_params, ctx, prompts, max_new=8)
    lut = generate(lut_params, ctx, prompts, max_new=8)
    print("standard serve :", ref.tolist())
    print("LUT serve      :", lut.tolist())
    print("(multiplier-free arithmetic — see DESIGN.md §2)")


if __name__ == "__main__":
    main()
