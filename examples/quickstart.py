"""Quickstart: train a small LM, plan its LUT budget per layer, convert,
and serve it multiplier-free.

  PYTHONPATH=src python examples/quickstart.py      (runs in <30s on CPU)
"""
import shutil

import jax

from repro.configs.base import get_config
from repro.core.convert import convert_params, conversion_summary
from repro.core.planner import ModelPlan, plan_model
from repro.data.pipeline import lm_stream
from repro.dist.checkpoint import latest_step, load_aux, save_checkpoint
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import count_params, init_params
from repro.serve import generate
from repro.train.trainer import TrainConfig, Trainer


def main():
    cfg = get_config("granite_8b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    print(f"model: {cfg.name} (reduced) — {count_params(model_specs(cfg)):,} params")

    shutil.rmtree("/tmp/quickstart_run", ignore_errors=True)  # fresh demo run
    tc = TrainConfig(peak_lr=1e-2, warmup_steps=5, total_steps=24,
                     checkpoint_every=12, out_dir="/tmp/quickstart_run")
    data = lm_stream(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    log = Trainer(ctx, tc, params, data).run(24)
    print(f"trained 24 steps: loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")
    trainer_params = Trainer(ctx, tc, params, data).params  # restored from ckpt

    # paper's post-training conversion, now per-layer planned: spend half the
    # uniform-chunk-2 LUT budget where it buys the most shift/add reduction
    uniform = plan_model(trainer_params, float("inf"), max_chunk=2)
    plan = plan_model(trainer_params, uniform.total_lut_bytes // 2, max_chunk=2)
    print("uniform plan  :", uniform.summary())
    print("planned (0.5x):", plan.summary())
    lut_params, report = convert_params(trainer_params, plan=plan)
    print("TableNet conversion:", conversion_summary(report))

    # the plan rides along with the checkpoint and survives restore
    ckpt_dir = "/tmp/quickstart_run/lut_ckpt"
    save_checkpoint(ckpt_dir, 0, trainer_params,
                    aux={"model_plan": plan.to_json()})
    restored = ModelPlan.from_json(
        load_aux(ckpt_dir, latest_step(ckpt_dir))["model_plan"]
    )
    assert dict(restored.layers) == dict(plan.layers)

    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab_size)
    ref = generate(trainer_params, ctx, prompts, max_new=8)
    # grouped serving: QKV / gate-up fuse into one LUT dispatch per step
    lut_ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    lut = generate(lut_params, lut_ctx, prompts, max_new=8)
    print("standard serve :", ref.tolist())
    print("LUT serve      :", lut.tolist())
    print("(multiplier-free arithmetic — see DESIGN.md §2)")


if __name__ == "__main__":
    main()
