"""Batched LUT-mode serving: continuous batching over a TableNet-converted
LM — the paper's technique as a first-class serving mode.

  PYTHONPATH=src python examples/serve_lut.py [--arch granite_8b] [--requests 6]
"""
import argparse
import time

import jax

from repro.configs.base import get_config
from repro.core.convert import convert_params, conversion_summary
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import BatchingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    lut_params, report = convert_params(params, chunk_size=1)
    print(f"serving {cfg.name} (reduced) in LUT mode")
    print("  " + conversion_summary(report))

    eng = BatchingEngine(lut_params, ctx, num_slots=args.slots, max_len=64)
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        plen = int(jax.random.randint(k, (), 3, 10))
        prompt = jax.random.randint(k, (plen,), 0, cfg.vocab_size)
        r = Request(uid=i, prompt=prompt, max_new=args.max_new)
        reqs.append(r)
        eng.submit(r)

    t0 = time.perf_counter()
    steps = 0
    while eng.step():
        steps += 1
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in reqs)
    print(f"{len(reqs)} requests on {args.slots} slots: {steps} decode steps, "
          f"{total} tokens in {dt:.1f}s ({total / dt:.1f} tok/s, CPU interpret)")
    for r in reqs:
        print(f"  req {r.uid}: prompt {list(map(int, r.prompt))} -> {r.generated}")


if __name__ == "__main__":
    main()
