"""Batched LUT-mode serving: the device-resident scheduler over a
TableNet-converted LM — per-layer planned conversion + grouped (fused
QKV / gate-up) decode, batched multi-slot admission and fused on-device
sampling.

  PYTHONPATH=src python examples/serve_lut.py [--arch granite_8b] \
      [--requests 6] [--temperature 0.8] [--top-k 40] [--admit per-slot]

Runs in <30s on CPU with the defaults.
"""
import argparse
import time

import jax

from repro.configs.base import get_config
from repro.core.convert import convert_params, conversion_summary
from repro.core.planner import plan_model
from repro.models.layers import Ctx, ExecCfg, SampleCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve import BatchingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--budget-frac", type=float, default=0.5,
                    help="LUT byte budget as a fraction of the uniform plan")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples on device")
    ap.add_argument("--top-k", type=int, default=0,
                    help="with --temperature: restrict draws to the top k")
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--admit", default="batched",
                    choices=("batched", "per-slot"),
                    help="admission schedule (token streams are identical)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    # grouped LUT decode: one fused dispatch per same-shape projection group
    ctx = Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))

    uniform = plan_model(params, float("inf"), max_chunk=2)
    budget = int(uniform.total_lut_bytes * args.budget_frac)
    plan = plan_model(params, budget, max_chunk=2)
    print(f"serving {cfg.name} (reduced) in planned LUT mode")
    print("  " + plan.summary()
          + f" (budget {budget / 2**20:.0f} MiB of"
          f" {uniform.total_lut_bytes / 2**20:.0f} MiB uniform)")
    lut_params, report = convert_params(params, plan=plan)
    print("  " + conversion_summary(report))

    if args.temperature > 0:
        mode = "top_k" if args.top_k > 0 else "temperature"
        sample = SampleCfg(mode=mode, temperature=args.temperature,
                           top_k=args.top_k)
    else:
        sample = SampleCfg()
    print(f"  sampling: {sample.mode}, admission: {args.admit}")
    eng = BatchingEngine(lut_params, ctx, num_slots=args.slots, max_len=64,
                         sample=sample, seed=args.seed, admit=args.admit)
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        plen = int(jax.random.randint(k, (), 3, 10))
        prompt = jax.random.randint(k, (plen,), 0, cfg.vocab_size)
        r = Request(uid=i, prompt=prompt, max_new=args.max_new)
        reqs.append(r)
        eng.submit(r)

    t0 = time.perf_counter()
    steps = 0
    while eng.step():
        steps += 1
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in reqs)
    print(f"{len(reqs)} requests on {args.slots} slots: {steps} decode steps, "
          f"{total} tokens in {dt:.1f}s ({total / dt:.1f} tok/s, CPU oracle; "
          f"{eng.readbacks} host readbacks)")
    for r in reqs:
        print(f"  req {r.uid}: prompt {list(map(int, r.prompt))} -> {r.generated}")


if __name__ == "__main__":
    main()
