"""Paper reproduction: linear / MLP / LeNet classifiers on the synthetic
MNIST stand-in — train, quantise inputs, convert to LUTs, compare.

Reproduces (offline-container versions of):
  Fig. 4/6: accuracy vs input bits (trend: saturation by ~3 bits)
  Fig. 5/7/8: LUT size vs shift-add tradeoff (analytic, exact)
  the LUT-path == quantised-model equivalence the whole paper rests on

  PYTHONPATH=src python examples/tablenet_mnist.py [--model mlp] [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.analysis import LINEAR_CLASSIFIER, MLP, figure_curve
from repro.core.convert import convert_params, conversion_summary
from repro.core.quantize import FixedPointFormat, Float16Format
from repro.data.synthetic import image_batch
from repro.models.layers import Ctx
from repro.models.paper_models import PAPER_MODELS
from repro.models.params import init_params


def train(model: str, steps: int, lr: float, seed=0):
    specs_fn, forward = PAPER_MODELS[model]
    ctx = Ctx(get_config("granite_8b", reduced=True))
    params = init_params(specs_fn(), jax.random.PRNGKey(seed))

    def loss_fn(p, x, y):
        logits = forward(p, x, ctx)
        return -jnp.mean(
            jnp.sum(jax.nn.log_softmax(logits) * jax.nn.one_hot(y, 10), -1)
        )

    @jax.jit
    def step(p, x, y):
        return jax.tree.map(lambda a, g: a - lr * g, p, jax.grad(loss_fn)(p, x, y))

    for s in range(steps):
        x, y = image_batch(128, s)
        params = step(params, x, y)
    return params, forward, ctx


def accuracy(forward, params, ctx, bits=None, n=1500):
    ok = tot = 0
    for s in range(n // 500):
        x, y = image_batch(500, 50_000 + s)
        if bits is not None:
            fmt = FixedPointFormat(bits, bits)
            x = fmt.dequantize(fmt.quantize(x))
        ok += int(jnp.sum(jnp.argmax(forward(params, x, ctx), -1) == y))
        tot += 500
    return ok / tot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="linear", choices=list(PAPER_MODELS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=0.3)
    args = ap.parse_args()

    params, forward, ctx = train(args.model, args.steps, args.lr)
    ref = accuracy(forward, params, ctx)
    print(f"[{args.model}] reference (fp32) accuracy: {ref:.3f}")
    print("accuracy vs input bits (paper Fig. 4/6 — expect ~3-bit saturation):")
    for bits in range(1, 9):
        print(f"  {bits} bits: {accuracy(forward, params, ctx, bits):.3f}")

    lut_params, report = convert_params(params, chunk_size=1, signed=False)
    print("conversion:", conversion_summary(report))
    x, y = image_batch(500, 99_999)
    a_ref = forward(params, x, ctx)
    a_lut = forward(lut_params, x, ctx)
    agree = float(jnp.mean(jnp.argmax(a_ref, -1) == jnp.argmax(a_lut, -1)))
    print(f"LUT path vs full model: argmax agreement {agree:.4f}, "
          f"max |dlogit| {float(jnp.abs(a_ref - a_lut).max()):.4f}")

    print("\nLUT size vs ops tradeoff (paper Fig. 5):")
    layers = LINEAR_CLASSIFIER if args.model == "linear" else MLP
    fmt = FixedPointFormat(3, 3) if args.model == "linear" else Float16Format()
    for r in figure_curve(layers, fmt)[:8]:
        print(f"  {r['mode']:9s} m={r['chunk']:2d}: {r['bytes']:>12,} B "
              f"{r['shift_adds']:>12,} shift-adds")


if __name__ == "__main__":
    main()
