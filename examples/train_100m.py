"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps
with checkpointing, restart, and metrics — the framework's full train path.

  PYTHONPATH=src python examples/train_100m.py --preset tiny --steps 50   # CPU-quick
  PYTHONPATH=src python examples/train_100m.py --preset 100m --steps 300

The 100m preset is the deliverable configuration (~110M params, granite-
style dense decoder); the tiny preset (~6M) exists so the driver can be
exercised end-to-end in CI on this CPU container.  Both run the identical
code path: deterministic sharded data -> jitted train step (remat, mixed
precision, AdamW + cosine) -> atomic checkpoints every --ckpt-every steps.
A mid-run restart (--demo-restart) kills and resumes from the checkpoint to
demonstrate fault tolerance.
"""
import argparse
import os
import shutil

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import lm_stream
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import count_params, init_params
from repro.train.trainer import TrainConfig, Trainer

PRESETS = {
    "tiny": ModelConfig(
        name="lm-tiny", family="dense", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
        vocab_pad_multiple=16,
    ),
    "100m": ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32000,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--out", default="/tmp/train_100m_run")
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--demo-restart", action="store_true",
                    help="stop halfway, then resume from the checkpoint")
    args = ap.parse_args()

    if args.fresh and os.path.isdir(args.out):
        shutil.rmtree(args.out)
    cfg = PRESETS[args.preset]
    ctx = Ctx(cfg, ex=ExecCfg(remat="dots"))
    specs = model_specs(cfg)
    print(f"{cfg.name}: {count_params(specs) / 1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    tc = TrainConfig(
        peak_lr=3e-4, warmup_steps=max(args.steps // 10, 5),
        total_steps=args.steps, checkpoint_every=args.ckpt_every,
        out_dir=args.out,
    )
    params = init_params(specs, jax.random.PRNGKey(0))

    def data_from(step):
        return lm_stream(cfg.vocab_size, args.seq, args.batch, seed=0,
                         start_step=step)

    t = Trainer(ctx, tc, params, data_from(0))
    if t.start_step:
        print(f"resumed from checkpoint at step {t.start_step}")
        t.data = data_from(t.start_step)

    if args.demo_restart and t.start_step == 0:
        half = args.steps // 2
        t.run(half)
        print(f"--- simulating preemption at step {half}; restarting ---")
        params2 = init_params(specs, jax.random.PRNGKey(0))
        t = Trainer(ctx, tc, params2, data_from(half))
        assert t.start_step == half, t.start_step

    log = t.run(args.steps)
    first = sum(r["loss"] for r in log[:3]) / max(len(log[:3]), 1)
    last = sum(r["loss"] for r in log[-3:]) / max(len(log[-3:]), 1)
    times = sorted(r["time_s"] for r in log)
    p50 = times[len(times) // 2]
    p99 = times[int(len(times) * 0.99) - 1]
    print(f"loss {first:.3f} -> {last:.3f}; step p50={p50:.2f}s p99={p99:.2f}s")
    print(f"checkpoints + metrics.jsonl in {args.out}")


if __name__ == "__main__":
    main()
