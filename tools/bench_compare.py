"""Compare benchmark JSON against a committed baseline; gate CI on regressions.

Input files are lists of ``{"name", "value", "unit"}`` rows as emitted by
``benchmarks/kernels.py --out`` / ``benchmarks/serving.py --out``.

Checks (any failure exits 1 with a per-row report):

* ``--baseline BASE --threshold 1.5`` — every time-like row (unit contains
  "us") present in both files must satisfy ``new <= threshold * old``; a
  gated baseline row that is *missing* from the new file fails with a clear
  message (a renamed bench row must update the committed baseline too, and
  malformed rows are rejected at load instead of raising ``KeyError``).
  ``--normalize`` divides each timing by the same file's ``lut_affine_jnp``
  row for its shape tag first, so the comparison is a ratio of ratios and
  robust to absolute machine speed differences between the baseline host
  and the CI runner.  ``matmul_ref`` rows are context only (never gated):
  the tiny matmul is dispatch-overhead dominated and far too noisy.
* ``--require-ge A B [--ge-slack 0.9]`` — in the new file,
  ``value[A] >= ge_slack * value[B]`` (e.g. grouped decode tokens/s must not
  fall below per-projection dispatch).
* ``--require-rows FILE`` — every row *name* in FILE (a committed companion
  baseline) must be present in the new file.  Catches silently renamed or
  dropped rows for files whose values are throughput (not gated by the
  time-row comparison above).

Usage:
  python tools/bench_compare.py NEW.json --normalize \
      --baseline benchmarks/baselines/kernels.json
  python tools/bench_compare.py NEW.json \
      --require-ge serve/lut_grouped_tok_per_s serve/lut_planned_tok_per_s \
      --require-rows benchmarks/baselines/serving.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys

_TAG = re.compile(r"_(B\d+_q\d+_p\d+_m\d+)$")
# normalizer: the jitted jnp-oracle row — the most run-to-run-stable timing
_REF_PREFIX = "kern/lut_affine_jnp_"
# context-only rows, never gated: the tiny matmul is dispatch-overhead
# dominated and swings an order of magnitude run to run
_UNGATED_PREFIXES = ("kern/matmul_ref_",)


def load(path: str) -> dict[str, dict]:
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        sys.exit(f"{path}: expected a JSON list of benchmark rows")
    for i, r in enumerate(rows):
        if not isinstance(r, dict) or "name" not in r or "value" not in r:
            sys.exit(
                f"{path}: row {i} is malformed (needs 'name' and 'value'): {r!r}"
            )
    return {r["name"]: r for r in rows}


def _normalized(rows: dict[str, dict]) -> dict[str, float]:
    """Each timing divided by its shape tag's lut_affine_jnp row (the
    _REF_PREFIX normalizer) from the same file; raw value if absent."""
    out = {}
    for name, r in rows.items():
        m = _TAG.search(name)
        ref = rows.get(f"{_REF_PREFIX}{m.group(1)}") if m else None
        if ref is not None and ref["name"] != name and ref["value"] > 0:
            out[name] = r["value"] / ref["value"]
        else:
            out[name] = r["value"]
    return out


def compare(base: dict, new: dict, threshold: float, normalize: bool) -> list[str]:
    failures = []
    bvals = _normalized(base) if normalize else {k: v["value"] for k, v in base.items()}
    nvals = _normalized(new) if normalize else {k: v["value"] for k, v in new.items()}
    compared = 0
    for name, brow in sorted(base.items()):
        if "us" not in brow.get("unit", ""):
            continue
        if name.startswith(_UNGATED_PREFIXES):
            continue
        if name.startswith(_REF_PREFIX) and normalize:
            continue  # the normalizer itself
        if name not in new:
            # a silently vanished row would un-gate itself; fail loudly
            print(f"  FAIL {name}: present in baseline, missing from new file")
            failures.append(
                f"baseline row {name!r} is missing from the new results "
                "(renamed or dropped? update the committed baseline too)"
            )
            continue
        compared += 1
        old_v, new_v = bvals[name], nvals[name]
        ratio = new_v / old_v if old_v > 0 else float("inf")
        status = "FAIL" if ratio > threshold else "ok"
        print(f"  {status:4s} {name}: {old_v:.3g} -> {new_v:.3g} ({ratio:.2f}x)")
        if ratio > threshold:
            failures.append(f"{name} regressed {ratio:.2f}x (> {threshold}x)")
    if compared == 0:
        failures.append("no comparable rows between baseline and new file")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly produced benchmark JSON")
    ap.add_argument("--baseline", help="committed baseline JSON to compare against")
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.5,
        help="fail when new > threshold * baseline (time rows)",
    )
    ap.add_argument(
        "--normalize",
        action="store_true",
        help="divide timings by each file's own lut_affine_jnp rows",
    )
    ap.add_argument(
        "--require-ge",
        nargs=2,
        metavar=("A", "B"),
        action="append",
        default=[],
        help="require value[A] >= ge-slack * value[B] in NEW",
    )
    ap.add_argument("--ge-slack", type=float, default=0.9)
    ap.add_argument(
        "--require-rows",
        metavar="FILE",
        help="every row name in FILE must exist in NEW",
    )
    args = ap.parse_args()

    new = load(args.new)
    failures: list[str] = []
    if args.require_rows:
        for name in load(args.require_rows):
            if name not in new:
                print(f"  FAIL {name}: required row missing from {args.new}")
                failures.append(
                    f"required row {name!r} missing (renamed or dropped? "
                    "update the committed companion baseline too)"
                )
    if args.baseline:
        print(
            f"comparing {args.new} against {args.baseline} "
            f"(threshold {args.threshold}x, normalize={args.normalize})"
        )
        failures += compare(load(args.baseline), new, args.threshold, args.normalize)
    for a, b in args.require_ge:
        if a not in new or b not in new:
            failures.append(f"--require-ge: missing row {a if a not in new else b}")
            continue
        va, vb = new[a]["value"], new[b]["value"]
        ok = va >= args.ge_slack * vb
        print(
            f"  {'ok' if ok else 'FAIL'} {a} ({va:.3g}) >= "
            f"{args.ge_slack} * {b} ({vb:.3g})"
        )
        if not ok:
            failures.append(f"{a}={va:.3g} < {args.ge_slack} * {b}={vb:.3g}")
    if failures:
        print("\nbench-gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("bench-gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
