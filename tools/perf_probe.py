import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb profiler: per-layer cost breakdown of one cell via depth probes.

  PYTHONPATH=src python tools/perf_probe.py granite_8b train_4k single \
      [--rules no_fsdp] [--exec '{"remat":"dots"}'] [--params lut]
Prints the per-LAYER collective ops (d2 - d1 diff), and per-layer
flops/bytes — the "profile" the optimization loop reads.
"""
import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import hlo_analysis as H
from repro.launch.dryrun import _raw_costs, lower_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("mesh", nargs="?", default="single")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--params", default="standard")
    ap.add_argument("--exec", default=None)
    ap.add_argument("--depths", default="1,2")
    args = ap.parse_args()
    ex = json.loads(args.exec) if args.exec else {}
    ex["inner_unroll"] = True

    d1, d2 = (int(x) for x in args.depths.split(","))
    stats = {}
    for d in (d1, d2):
        _, compiled, _, _, _ = lower_cell(
            args.arch, args.shape, args.mesh, ex,
            cfg_overrides={"num_layers": d}, rules=args.rules,
            params_mode=args.params,
        )
        stats[d] = (
            _raw_costs(compiled),
            H.collective_stats(compiled.as_text()).by_op,
        )

    (c1, ops1), (c2, ops2) = stats[d1], stats[d2]
    dd = d2 - d1
    print(f"== per-layer (depth {d2} - depth {d1}) ==")
    print(f"flops/layer      : {(c2[0] - c1[0]) / dd / 1e9:10.2f} GF")
    print(f"hbm bytes/layer  : {(c2[1] - c1[1]) / dd / 2**30:10.2f} GiB")
    print(f"link bytes/layer : {(c2[2] - c1[2]) / dd / 2**20:10.2f} MiB")
    print("-- per-layer collectives --")
    for op in sorted(set(ops1) | set(ops2)):
        a = ops1.get(op, {"count": 0, "link_bytes": 0})
        b = ops2.get(op, {"count": 0, "link_bytes": 0})
        dc = (b["count"] - a["count"]) / dd
        db = (b["link_bytes"] - a["link_bytes"]) / dd / 2**20
        print(f"  {op:20s} {dc:6.1f} ops/layer  {db:10.2f} MiB/layer")
    print("-- depth-1 totals (embed/head/loss overhead) --")
    print(
        f"flops {c1[0] / 1e9:.2f} GF, hbm {c1[1] / 2**30:.2f} GiB, "
        f"link {c1[2] / 2**20:.2f} MiB"
    )
    for op, rec in sorted(ops1.items()):
        print(f"  {op:20s} {rec['count']:5d} ops {rec['link_bytes']/2**20:10.2f} MiB")


if __name__ == "__main__":
    main()
