"""Regenerate the §Dry-run/§Roofline tables inside EXPERIMENTS.md from
results/dryrun.json (between the AUTOGEN markers)."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline_report import markdown_table  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def dryrun_summary(records) -> str:
    ok = [r for r in records if r["status"] == "ok"]
    sk = [r for r in records if r["status"] == "skipped"]
    lines = [
        f"* cells compiled OK: **{len(ok)}** (both meshes), skipped per spec: "
        f"**{len(sk)}**, failures: **{len(records) - len(ok) - len(sk)}**",
        "",
        "| arch | shape | mesh | per-device HLO GFLOPs | per-device HBM GiB "
        "| per-device link MiB | args GiB | temp GiB | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['flops_per_device'] / 1e9:.1f} "
            f"| {r['hbm_bytes_per_device'] / 2**30:.2f} "
            f"| {r['collectives']['link_bytes'] / 2**20:.1f} "
            f"| {m.get('argument_mib', 0) / 1024:.2f} "
            f"| {m.get('temp_mib', 0) / 1024:.2f} "
            f"| {r.get('compile_s', 0):.0f} |"
        )
    skips = [
        f"  * {r['arch']} {r['shape']}: {r['reason']}"
        for r in sk
        if r["mesh"] == "single"
    ]
    skipped = "\n".join(sorted(set(skips)))
    return "\n".join(lines) + "\n\nSkipped cells (spec rule):\n" + skipped


def main():
    with open(os.path.join(ROOT, "results", "dryrun.json")) as f:
        records = json.load(f)
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    for tag, content in [
        ("DRYRUN", dryrun_summary(records)),
        ("ROOFLINE", markdown_table(records)),
    ]:
        start, end = f"<!-- AUTOGEN:{tag} -->", f"<!-- /AUTOGEN:{tag} -->"
        i, j = text.index(start) + len(start), text.index(end)
        text = text[:i] + "\n" + content + "\n" + text[j:]
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
