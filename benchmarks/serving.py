"""Serving throughput bench (reduced LM, CPU): dense vs planned-LUT decode.

Measures steady-state *decode* tokens/s (prefill once, then timed decode
steps) for:

* ``dense``        — standard matmul projections
* ``lut_planned``  — per-layer ``plan_model`` conversion, one LUT dispatch
                     per projection per decode step (the pre-fusion path)
* ``lut_grouped``  — same converted params routed through the fused
                     ``lut_affine_grouped`` path (``ExecCfg.lut_grouped``):
                     same-shape projections (QKV, gate/up) pack the input
                     once and execute as one grouped gather

On TPU the LUT gather path is memory-bound and the bitplane-MXU path
compute-bound (see EXPERIMENTS.md §Perf); this CPU bench demonstrates the
paths end-to-end and tracks the grouped-vs-dispatch ratio in CI
(``BENCH_serving.json``).
"""
from __future__ import annotations

import statistics
import time

import jax

from repro.configs.base import get_config
from repro.core.convert import convert_params
from repro.core.planner import plan_model
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import make_cache, make_decode_step, make_prefill_step


def _decode_tps(params, ctx: Ctx, prompts, steps: int, reps: int = 3) -> float:
    """Median decode tokens/s over ``reps`` timed runs of ``steps`` steps."""
    B, S = prompts.shape
    cache = make_cache(ctx.cfg, B, S + steps * (reps + 2), ctx)
    prefill = jax.jit(make_prefill_step(ctx))
    decode = jax.jit(make_decode_step(ctx))
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    tok = jax.numpy.argmax(logits[:, -1], -1).astype(jax.numpy.int32)[:, None]
    # warmup: compile + one full round
    for _ in range(2):
        tok, _, cache = decode(params, cache, tok)
    jax.block_until_ready(tok)
    rates = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(steps):
            tok, _, cache = decode(params, cache, tok)
        jax.block_until_ready(tok)
        rates.append(B * steps / (time.perf_counter() - t0))
    return statistics.median(rates)


def rows(tiny: bool = False) -> list[tuple[str, float, str]]:
    cfg = get_config("granite_8b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))

    # per-layer planning: half the uniform-chunk-2 footprint forces the
    # greedy pass to mix chunk sizes rather than apply one plan everywhere
    uniform = plan_model(params, float("inf"), max_chunk=2)
    budget = uniform.total_lut_bytes // 2
    mplan = plan_model(params, budget, max_chunk=2)
    lut_params, report = convert_params(params, plan=mplan)

    B, S = (2, 4) if tiny else (4, 8)
    steps = 8 if tiny else 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    modes = [
        ("dense", params, ExecCfg(remat="none")),
        ("lut_planned", lut_params, ExecCfg(remat="none")),
        ("lut_grouped", lut_params, ExecCfg(remat="none", lut_grouped=True)),
    ]
    shape_note = f"B{B} x {steps} decode steps"
    out: list[tuple[str, float, str]] = [
        ("serve/plan_budget_mib", round(budget / 2**20, 2), "global LUT budget"),
        ("serve/plan_table_mib", round(mplan.total_lut_bytes / 2**20, 2),
         f"{len(mplan.layers)} planned layers"),
        ("serve/plan_shift_add_ops", float(mplan.total_shift_add_ops),
         f"vs {uniform.total_shift_add_ops} uniform"),
    ]
    for name, p, ex in modes:
        tps = _decode_tps(p, Ctx(cfg, ex=ex), prompts, steps)
        out.append((f"serve/{name}_tok_per_s", round(tps, 2), shape_note))
    return out


def main():
    """CI entry point: run (optionally tiny) shapes, emit BENCH_serving.json."""
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small batch/few steps (CI smoke-bench)")
    ap.add_argument("--out", default=None, help="write JSON rows to this path")
    args = ap.parse_args()
    payload = [
        {"name": name, "value": value, "unit": unit}
        for name, value, unit in rows(tiny=args.tiny)
    ]
    text = json.dumps(payload, indent=1)
    print(text)
    if args.out:
        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
