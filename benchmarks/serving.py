"""Serving throughput bench (reduced LM, CPU): dense vs planned-LUT decode.

Measures steady-state *decode* tokens/s (prefill once, then timed decode
steps) for:

* ``dense``        — standard matmul projections
* ``lut_planned``  — per-layer ``plan_model`` conversion in the flat
                     per-projection layout (``group_siblings=False``), one
                     LUT dispatch per projection per decode step
* ``lut_grouped_prestacked`` — the same plan converted with pre-stacked
                     sibling groups (``LUTGroup`` leaves, the default
                     layout) and routed through ``ExecCfg.lut_grouped``:
                     same-shape projections (K/V, gate/up) pack the input
                     once and execute as one grouped gather straight from
                     the stored ``(G, k, E, p)`` leaf — no per-step stack

Engine-level rows measure the device-resident ``BatchingEngine`` end to
end (admission prefills + decode + the one packed readback per step):

* ``engine_batched_admit`` — multi-slot batched prefill admission
* ``engine_per_slot_admit`` — one request per prefill call (the retired
  scheduler's admission pattern; CI gates batched >= per-slot)
* ``engine_paged_admit``   — batched admission over the paged KV cache
  (page_size=8, on-demand page allocation + prefix sharing).  CI gates
  paged >= 0.5x the dense-rectangle batched admission: on tiny CPU
  shapes the per-layer one-hot page write + table gather adds a measured
  ~1.7x dispatch-bound overhead per decode step (page-size invariant, so
  it is emulation cost rather than pool-traversal cost); the gate guards
  against structural collapses (per-step recompiles, quadratic table
  work), not that constant
* ``engine_sampled``       — temperature sampling fused on device
* ``engine_moe_dense`` / ``engine_moe_lut`` — a reduced qwen2-moe config
  served end to end with dense experts (``lax.ragged_dot`` grouped GEMM)
  vs ``convert_experts=True`` LUT experts (the ragged ``lut_affine_experts``
  path, gate/up pre-stacked): the multiplier-free MoE serving path is
  exercised and tracked per commit
* ``engine_weight_lut`` / ``engine_tl1`` — table-FAMILY head-to-head: the
  weight-table champion conversion vs the same model planned entirely into
  the TL1 activation-side family (ternary weights as packed base-3 pair
  indices, per-token 9-entry LUT built each decode step);
  ``plan_tl1_table_mib`` records the ~16x persistent-bytes gap alongside

The heavy-traffic lane (``serve/heavy_*`` rows, scaled up by ``--heavy``
for the weekly scheduled run) drives the paged engine open-loop: Poisson
arrivals, mixed short/long prompts, half the requests opening with a
shared 16-token system prefix (so admission maps its pages instead of
re-prefilling), mixed response budgets.  Per mode (dense / planned-LUT /
grouped-LUT) it reports p50/p99 per-request latency and steady tokens/s
per slot.

On TPU the LUT gather path is memory-bound and the bitplane-MXU path
compute-bound (see EXPERIMENTS.md §Perf); this CPU bench demonstrates the
paths end-to-end and tracks the grouped-vs-dispatch ratio in CI
(``BENCH_serving.json``).
"""
from __future__ import annotations

import statistics
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.convert import convert_params
from repro.core.planner import plan_model
from repro.kernels.lut_affine.autotune import attach_tuned_blocks
from repro.models.layers import Ctx, ExecCfg, SampleCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve import (
    BatchingEngine,
    Request,
    make_cache,
    make_decode_step,
    make_prefill_step,
)


def _decode_state(params, ctx: Ctx, prompts, steps: int, reps: int) -> dict:
    """Prefill + compile + warm a decode loop; returns resumable state."""
    B, S = prompts.shape
    cache = make_cache(ctx.cfg, B, S + steps * (reps + 2), ctx)
    prefill = jax.jit(make_prefill_step(ctx))
    decode = jax.jit(make_decode_step(ctx))
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    tok = jax.numpy.argmax(logits[:, -1], -1).astype(jax.numpy.int32)[:, None]
    # warmup: compile + one settled round
    for _ in range(2):
        tok, _, cache = decode(params, cache, tok)
    jax.block_until_ready(tok)
    return {"params": params, "decode": decode, "cache": cache, "tok": tok}


def _timed_window(state: dict, steps: int) -> float:
    """Advance one timed window of ``steps`` decode steps; returns seconds."""
    tok, cache = state["tok"], state["cache"]
    t0 = time.perf_counter()
    for _ in range(steps):
        tok, _, cache = state["decode"](state["params"], cache, tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    state["tok"], state["cache"] = tok, cache
    return dt


def _decode_tps(named_runs, prompts, steps: int, reps: int = 7) -> dict:
    """Decode tokens/s per mode, measured in interleaved paired rounds.

    The CI boxes share cores, and machine-load drift between one mode's
    measurement and the next can exceed the few-percent effect under test
    (grouped vs per-projection dispatch).  So the modes' timed windows are
    interleaved into rounds (back-to-back, ~100ms apart) and each mode
    reports its MEDIAN window across rounds: load drift is common-mode
    across a round, and the median discards the stalled windows entirely.
    Sequential per-mode phases with independent best-of were measured to
    wobble past the gate's 0.9 slack on shared runners."""
    B = prompts.shape[0]
    states = {
        name: _decode_state(params, ctx, prompts, steps, reps)
        for name, params, ctx in named_runs
    }
    rounds = []
    for _ in range(reps):
        rounds.append(
            {name: _timed_window(state, steps) for name, state in states.items()}
        )
    return {
        name: B * steps / statistics.median(r[name] for r in rounds)
        for name in states
    }


def _engine_run(
    params, ctx, *, admit, sample, prompts, max_new, num_slots, page_size=None
) -> float:
    """One full engine run (admissions + decode to drain); returns seconds.
    The jitted steps are lru-cached per (ctx, sample, eos, paged), so
    repeated engine construction here never recompiles."""
    eng = BatchingEngine(
        params, ctx, num_slots=num_slots, max_len=32,
        sample=sample, admit=admit, prefill_bucket=8, page_size=page_size,
    )
    reqs = [
        Request(uid=i, prompt=p, max_new=max_new) for i, p in enumerate(prompts)
    ]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    return dt


def _engine_tps(params, ctx, tiny: bool, reps: int = 9) -> dict:
    """End-to-end engine tokens/s per scheduler config, interleaved rounds
    + median (same rationale as _decode_tps: machine-load drift on shared
    CI runners is common-mode within a round).  The order WITHIN a round
    rotates per round — a fixed order gives the first config a systematic
    cold-cache penalty that can exceed the few-ms admission effect under
    test."""
    num_slots = 2
    max_new = 8 if tiny else 16
    key = jax.random.PRNGKey(2)
    prompts = []
    for i in range(2 * num_slots):
        key, k = jax.random.split(key)
        plen = 3 + i % 4
        prompts.append(jax.random.randint(k, (plen,), 0, ctx.cfg.vocab_size))
    total = len(prompts) * max_new
    configs = {
        "engine_batched_admit": dict(admit="batched", sample=SampleCfg()),
        "engine_per_slot_admit": dict(admit="per-slot", sample=SampleCfg()),
        "engine_paged_admit": dict(
            admit="batched", sample=SampleCfg(), page_size=8
        ),
        "engine_sampled": dict(
            admit="batched", sample=SampleCfg(mode="temperature", temperature=0.8)
        ),
    }
    def run(kw):
        return _engine_run(
            params, ctx, prompts=prompts, max_new=max_new,
            num_slots=num_slots, **kw
        )
    for kw in configs.values():  # warmup: compile both steps per config
        run(kw)
    names = list(configs)
    rounds = []
    for i in range(reps):
        order = names[i % len(names) :] + names[: i % len(names)]
        rounds.append({name: run(configs[name]) for name in order})
    return {
        name: total / statistics.median(r[name] for r in rounds)
        for name in configs
    }


def _engine_moe_tps(tiny: bool, reps: int = 7) -> dict:
    """End-to-end engine tokens/s for a reduced MoE config, dense experts
    vs converted (LUT) experts — interleaved rotated rounds + median like
    ``_engine_tps`` (shared-runner load drift is common-mode in a round)."""
    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(3))
    lut_params, _ = convert_params(params, chunk_size=1, convert_experts=True)
    runs = {
        "engine_moe_dense": (params, Ctx(cfg, ex=ExecCfg(remat="none"))),
        "engine_moe_lut": (
            lut_params,
            Ctx(cfg, ex=ExecCfg(remat="none", lut_grouped=True)),
        ),
    }
    num_slots = 2
    max_new = 8 if tiny else 16
    key = jax.random.PRNGKey(4)
    prompts = []
    for i in range(2 * num_slots):
        key, k = jax.random.split(key)
        prompts.append(jax.random.randint(k, (3 + i % 4,), 0, cfg.vocab_size))
    total = len(prompts) * max_new

    def run(name):
        p, ctx = runs[name]
        return _engine_run(
            p, ctx, admit="batched", sample=SampleCfg(), prompts=prompts,
            max_new=max_new, num_slots=num_slots,
        )

    names = list(runs)
    for name in names:  # warmup: compile prefill+decode per param layout
        run(name)
    rounds = []
    for i in range(reps):
        order = names[i % len(names):] + names[: i % len(names)]
        rounds.append({name: run(name) for name in order})
    return {
        name: total / statistics.median(r[name] for r in rounds)
        for name in runs
    }


def _engine_family_tps(params, mplan, cfg, tiny: bool, reps: int = 7) -> dict:
    """Head-to-head between the two table FAMILIES serving the same reduced
    LM end to end through the :class:`BatchingEngine`:

    * ``engine_weight_lut`` — the weight-table champion: the planned
      conversion under ``serving_model_plan`` in the pre-stacked grouped
      layout (the bench's best weight-family configuration)
    * ``engine_tl1`` — the SAME model planned entirely into the TL1
      activation-side family (ternary weights packed as base-3 pair
      indices, per-token 9-entry LUT built each decode step)

    Interleaved rotated rounds + median, like the other engine lanes."""
    tl1_plan = serving_tl1_plan(tiny, params)
    weight_params, _ = convert_params(params, plan=mplan)
    tl1_params, _ = convert_params(params, plan=tl1_plan)
    ex = ExecCfg(remat="none", lut_grouped=True)
    runs = {
        "engine_weight_lut": (weight_params, Ctx(cfg, ex=ex)),
        "engine_tl1": (tl1_params, Ctx(cfg, ex=ex)),
    }
    num_slots = 2
    max_new = 8 if tiny else 16
    key = jax.random.PRNGKey(6)
    prompts = []
    for i in range(2 * num_slots):
        key, k = jax.random.split(key)
        prompts.append(jax.random.randint(k, (3 + i % 4,), 0, cfg.vocab_size))
    total = len(prompts) * max_new

    def run(name):
        p, ctx = runs[name]
        return _engine_run(
            p, ctx, admit="batched", sample=SampleCfg(), prompts=prompts,
            max_new=max_new, num_slots=num_slots,
        )

    names = list(runs)
    for name in names:  # warmup: compile prefill+decode per param layout
        run(name)
    rounds = []
    for i in range(reps):
        order = names[i % len(names):] + names[: i % len(names)]
        rounds.append({name: run(name) for name in order})
    out = {
        name: total / statistics.median(r[name] for r in rounds)
        for name in runs
    }
    out["plan_tl1_table_mib"] = tl1_plan.total_lut_bytes / 2**20
    return out


def _heavy_workload(vocab: int, n_req: int, seed: int = 5):
    """Open-loop traffic: Poisson arrivals (exponential gaps), a 50/50 mix
    of short and long prompts, half of them opening with a shared 16-token
    system prefix (two pages at ps=8 — admission maps them instead of
    re-prefilling), and mixed response budgets."""
    rng = np.random.default_rng(seed)
    sys_prefix = rng.integers(1, vocab, size=16)
    arrivals = np.cumsum(rng.exponential(0.002, n_req))
    prompts, max_news = [], []
    for _ in range(n_req):
        plen = int(rng.integers(3, 8) if rng.random() < 0.5
                   else rng.integers(12, 21))
        body = rng.integers(1, vocab, size=plen)
        if rng.random() < 0.5:
            body = np.concatenate([sys_prefix, body])
        prompts.append(body.astype(np.int32))
        max_news.append(int(rng.integers(4, 12)))
    return arrivals, prompts, max_news


def _heavy_run(params, ctx, *, arrivals, prompts, max_news, num_slots,
               max_len, page_size) -> dict:
    """Drive the paged engine open-loop against timestamped arrivals;
    returns p50/p99 per-request latency (ms) and tokens/s per slot."""
    eng = BatchingEngine(
        params, ctx, num_slots=num_slots, max_len=max_len, page_size=page_size
    )
    reqs = [
        Request(uid=i, prompt=jax.numpy.asarray(p, jax.numpy.int32), max_new=m)
        for i, (p, m) in enumerate(zip(prompts, max_news))
    ]
    finish: dict[int, float] = {}
    i = 0
    t0 = time.perf_counter()
    while len(finish) < len(reqs):
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        active = eng.step()
        now = time.perf_counter() - t0
        for r in reqs[:i]:
            if r.done and r.uid not in finish:
                finish[r.uid] = now
        if not active and i < len(reqs):
            time.sleep(max(0.0, float(arrivals[i]) - now))
    wall = time.perf_counter() - t0
    lats = sorted(finish[r.uid] - arrivals[r.uid] for r in reqs)
    total = sum(len(r.generated) for r in reqs)
    return {
        "p50_ms": 1e3 * lats[len(lats) // 2],
        "p99_ms": 1e3 * lats[min(len(lats) - 1, int(0.99 * len(lats)))],
        "tok_per_s_per_slot": total / (wall * num_slots),
    }


def _heavy_rows(modes, tiny: bool, heavy: bool) -> list[tuple[str, float, str]]:
    n_req = 48 if heavy else (10 if tiny else 16)
    num_slots, max_len, page_size = 4, 48, 8
    out: list[tuple[str, float, str]] = []
    note = (
        f"ms p-latency / tok rate, {n_req} req open-loop Poisson, "
        f"{num_slots} slots, paged ps={page_size}, shared-prefix 0.5"
    )
    for name, params, ctx in modes:
        kw = dict(num_slots=num_slots, max_len=max_len, page_size=page_size)
        arrivals, prompts, max_news = _heavy_workload(ctx.cfg.vocab_size, n_req)
        # warm pass compiles every prefill bucket + the decode step; the
        # timed pass then measures scheduling, not compilation
        _heavy_run(params, ctx, arrivals=arrivals, prompts=prompts,
                   max_news=max_news, **kw)
        stats = _heavy_run(params, ctx, arrivals=arrivals, prompts=prompts,
                           max_news=max_news, **kw)
        for stat, value in stats.items():
            out.append((f"serve/heavy_{name}_{stat}", round(value, 2), note))
    return out


def serving_model_plan(tiny: bool = False, params=None):
    """The bench's planned conversion: uniform plan, halved-budget knapsack
    over the widened frontier, decode-batch-tuned Pallas blocks attached.
    Also the source of the committed autotune baseline's shape points
    (``--dump-plan`` -> ``repro.kernels.lut_affine.autotune write``)."""
    if params is None:
        cfg = get_config("granite_8b", reduced=True)
        params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    # per-layer planning: half the uniform-chunk-2 footprint forces the
    # greedy pass to mix chunk sizes rather than apply one plan everywhere
    uniform = plan_model(params, float("inf"), max_chunk=2)
    budget = uniform.total_lut_bytes // 2
    # widened frontier: sigma-factored bitplane_shift tables (radix-grouped
    # mantissa planes, i8 storage where safe) compete with plain bitplane
    # point-by-point; the knapsack picks the cheapest-ops plan per budget
    mplan = plan_model(
        params,
        budget,
        max_chunk=2,
        modes=("bitplane", "bitplane_shift"),
        radices=(1, 2, 4),
        table_formats=(None, "i8"),
    )
    mplan = attach_tuned_blocks(mplan, batch=2 if tiny else 4)
    return mplan, uniform, budget


def serving_tl1_plan(tiny: bool = False, params=None):
    """The family head-to-head's TL1 conversion: the whole model planned
    into the activation-side family, decode-tuned blocks attached.  Its
    shape points join the committed autotune baseline (``--dump-plan``
    merges them under ``tl1/``-prefixed keys)."""
    if params is None:
        cfg = get_config("granite_8b", reduced=True)
        params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    tl1 = plan_model(params, float("inf"), families=("tl1",))
    return attach_tuned_blocks(tl1, batch=2 if tiny else 4)


def rows(tiny: bool = False, heavy: bool = False) -> list[tuple[str, float, str]]:
    cfg = get_config("granite_8b", reduced=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))

    mplan, uniform, budget = serving_model_plan(tiny, params)
    # same per-layer plans, two layouts: flat per-projection vs pre-stacked
    lut_params, _ = convert_params(params, plan=mplan, group_siblings=False)
    lut_grouped_params, report = convert_params(params, plan=mplan)

    B, S = (2, 4) if tiny else (4, 8)
    steps = 32 if tiny else 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    modes = [
        ("dense", params, ExecCfg(remat="none")),
        ("lut_planned", lut_params, ExecCfg(remat="none")),
        (
            "lut_grouped_prestacked",
            lut_grouped_params,
            ExecCfg(remat="none", lut_grouped=True),
        ),
    ]
    shape_note = f"B{B} x {steps} decode steps"
    out: list[tuple[str, float, str]] = [
        ("serve/plan_budget_mib", round(budget / 2**20, 2), "global LUT budget"),
        ("serve/plan_table_mib", round(mplan.total_lut_bytes / 2**20, 2),
         f"{len(mplan.layers)} planned layers"),
        ("serve/plan_shift_add_ops", float(mplan.total_shift_add_ops),
         f"vs {uniform.total_shift_add_ops} uniform"),
        ("serve/plan_groups", float(len(mplan.groups)),
         f"{report.grouped} LUTGroup nodes emitted"),
    ]
    named_runs = [(name, p, Ctx(cfg, ex=ex)) for name, p, ex in modes]
    for name, tps in _decode_tps(named_runs, prompts, steps).items():
        out.append((f"serve/{name}_tok_per_s", round(tps, 2), shape_note))
    eng_note = "end-to-end engine run, 2 slots, 4 requests"
    for name, tps in _engine_tps(params, Ctx(cfg, ex=ExecCfg(remat="none")),
                                 tiny).items():
        out.append((f"serve/{name}_tok_per_s", round(tps, 2), eng_note))
    moe_note = "end-to-end MoE engine run, 2 slots, 4 requests"
    for name, tps in _engine_moe_tps(tiny).items():
        out.append((f"serve/{name}_tok_per_s", round(tps, 2), moe_note))
    fam = _engine_family_tps(params, mplan, cfg, tiny)
    out.append(("serve/plan_tl1_table_mib",
                round(fam.pop("plan_tl1_table_mib"), 3),
                f"vs {round(mplan.total_lut_bytes / 2**20, 2)} weight-champ"))
    fam_note = "end-to-end engine run, 2 slots, 4 requests; family head-to-head"
    for name, tps in fam.items():
        out.append((f"serve/{name}_tok_per_s", round(tps, 2), fam_note))
    out.extend(_heavy_rows(named_runs, tiny, heavy))
    return out


def main():
    """CI entry point: run (optionally tiny) shapes, emit BENCH_serving.json."""
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small batch/few steps (CI smoke-bench)")
    ap.add_argument("--heavy", action="store_true",
                    help="scale the open-loop traffic lane up (weekly run)")
    ap.add_argument("--out", default=None, help="write JSON rows to this path")
    ap.add_argument("--dump-plan", default=None,
                    help="write the serving ModelPlan (with tuned blocks) "
                         "as JSON — feeds the autotune baseline CLI")
    args = ap.parse_args()
    if args.dump_plan:
        import dataclasses

        mplan, _, _ = serving_model_plan(tiny=args.tiny)
        tl1 = serving_tl1_plan(tiny=args.tiny)
        # merge both families' dispatch shapes into ONE plan dump (tl1/
        # key prefix keeps the layer keys disjoint) so the committed
        # autotune baseline re-searches weight AND tl1 tune points
        merged = dataclasses.replace(
            mplan,
            layers={**mplan.layers,
                    **{f"tl1/{k}": v for k, v in tl1.layers.items()}},
            groups=mplan.groups + tuple(
                tuple(f"tl1/{m}" for m in g) for g in tl1.groups
            ),
            copies={**mplan.copies,
                    **{f"tl1/{k}": v for k, v in tl1.copies.items()}},
        )
        with open(args.dump_plan, "w") as f:
            json.dump(merged.to_json(), f, indent=1)
            f.write("\n")
        if not args.out:
            return
    payload = [
        {"name": name, "value": value, "unit": unit}
        for name, value, unit in rows(tiny=args.tiny, heavy=args.heavy)
    ]
    text = json.dumps(payload, indent=1)
    print(text)
    if args.out:
        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
