"""Serving throughput bench (reduced LM, CPU): standard vs LUT-converted.

On TPU the LUT gather path is memory-bound and the bitplane-MXU path
compute-bound (see EXPERIMENTS.md §Perf); this CPU bench just demonstrates
both paths end-to-end and reports tokens/s for context.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.convert import convert_params
from repro.models.layers import Ctx, ExecCfg
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import generate


def rows() -> list[tuple[str, float, str]]:
    cfg = get_config("granite_8b", reduced=True)
    ctx = Ctx(cfg, ex=ExecCfg(remat="none"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)

    out = []
    for name, p, c in [
        ("standard", params, ctx),
        ("lut_gather", convert_params(params, chunk_size=1)[0], ctx),
        ("binary_matmul", params, Ctx(cfg, ex=ExecCfg(remat="none", linear_mode="binary_matmul"))),
    ]:
        t0 = time.perf_counter()
        toks = generate(p, c, prompts, max_new=16)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        tps = 4 * 16 / dt
        out.append((f"serve/{name}_tok_per_s", round(tps, 2), "4 seqs x 16 new"))
    return out
