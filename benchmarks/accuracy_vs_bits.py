"""Paper Figs. 4/6: accuracy vs input bit-width for the linear classifier.

MNIST is unavailable offline; the synthetic stand-in (class-conditional blob
patterns) reproduces the paper's *trend*: accuracy saturates by ~3 input
bits and does not improve with more precision.  The LUT path is evaluated
with the *same tables* at every bit width (exactness is tested separately —
here we measure classification accuracy of the quantised-input model).

The ``fig4/tl1_*`` rows extend the sweep down the table-bytes axis with the
TL1 activation-side family: the classifier's weights ternarized (absmean)
and served from packed base-3 pair indices at ~16x fewer persistent table
bytes than the weight family, across activation bit widths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.convert import convert_params
from repro.core.lut import LUTPlan
from repro.core.lut_tl1 import TL1Plan
from repro.core.planner import ModelPlan
from repro.core.quantize import FixedPointFormat
from repro.data.synthetic import image_batch
from repro.models.layers import Ctx
from repro.models.paper_models import linear_classifier_forward, linear_classifier_specs
from repro.models.params import init_params


def train_linear(steps=400, batch=256, lr=0.3, seed=0):
    ctx = Ctx(get_config("granite_8b", reduced=True))
    params = init_params(linear_classifier_specs(), jax.random.PRNGKey(seed))

    def loss_fn(p, x, y):
        logits = linear_classifier_forward(p, x, ctx)
        onehot = jax.nn.one_hot(y, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(p, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for s in range(steps):
        x, y = image_batch(batch, s, seed=seed)
        params = step(params, x, y)
    return params, ctx


def accuracy(params, ctx, bits: int | None, n=2000, seed=0) -> float:
    correct = tot = 0
    for s in range(n // 500):
        x, y = image_batch(500, 10_000 + s, seed=seed)
        if bits is not None:
            fmt = FixedPointFormat(bits, bits)  # inputs in [0, 1)
            x = fmt.dequantize(fmt.quantize(x))
        logits = linear_classifier_forward(params, x, ctx)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y))
        tot += 500
    return correct / tot


def tl1_accuracy(params, ctx, act_bits: int | None, n=2000, seed=0) -> float:
    """Accuracy with ``fc`` converted to the TL1 family (ternary weights,
    activation-side LUT) at ``act_bits`` activation quantization."""
    q, p = params["fc"]["w"].shape
    plan = ModelPlan({"fc": TL1Plan(q, p, act_bits=act_bits)})
    conv, _ = convert_params(params, plan=plan)
    correct = tot = 0
    for s in range(n // 500):
        x, y = image_batch(500, 10_000 + s, seed=seed)
        logits = linear_classifier_forward(conv, x, ctx)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y))
        tot += 500
    return correct / tot


def rows() -> list[tuple[str, float, str]]:
    params, ctx = train_linear()
    ref = accuracy(params, ctx, None)
    out = [("fig4/reference_fp32", round(ref, 4), "full precision")]
    for bits in range(1, 9):
        acc = accuracy(params, ctx, bits)
        out.append((f"fig4/bits_{bits}", round(acc, 4), f"delta={acc - ref:+.4f}"))
    # accuracy vs TABLE BYTES: the TL1 family's design point — ternary
    # weights cost q*p/4 persistent bytes vs the weight family's tables
    # (reference: the int8-input bitplane chunk-2 plan, the same input
    # regime the fig4 sweep saturates in)
    q, p = params["fc"]["w"].shape
    weight_bytes = LUTPlan(
        q, p, 2, FixedPointFormat(8, 8, signed=False), mode="bitplane"
    ).total_lut_bytes
    for act_bits in (None, 8, 4, 2):
        acc = tl1_accuracy(params, ctx, act_bits)
        tl1_bytes = TL1Plan(q, p, act_bits=act_bits).total_lut_bytes
        label = "fp" if act_bits is None else f"a{act_bits}"
        out.append((
            f"fig4/tl1_{label}",
            round(acc, 4),
            f"{tl1_bytes}B tables (weight-family {weight_bytes}B), "
            f"delta={acc - ref:+.4f}",
        ))
    return out
