"""Paper Figs. 4/6: accuracy vs input bit-width for the linear classifier.

MNIST is unavailable offline; the synthetic stand-in (class-conditional blob
patterns) reproduces the paper's *trend*: accuracy saturates by ~3 input
bits and does not improve with more precision.  The LUT path is evaluated
with the *same tables* at every bit width (exactness is tested separately —
here we measure classification accuracy of the quantised-input model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.quantize import FixedPointFormat
from repro.data.synthetic import image_batch
from repro.models.layers import Ctx
from repro.models.paper_models import linear_classifier_forward, linear_classifier_specs
from repro.models.params import init_params


def train_linear(steps=400, batch=256, lr=0.3, seed=0):
    ctx = Ctx(get_config("granite_8b", reduced=True))
    params = init_params(linear_classifier_specs(), jax.random.PRNGKey(seed))

    def loss_fn(p, x, y):
        logits = linear_classifier_forward(p, x, ctx)
        onehot = jax.nn.one_hot(y, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(p, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for s in range(steps):
        x, y = image_batch(batch, s, seed=seed)
        params = step(params, x, y)
    return params, ctx


def accuracy(params, ctx, bits: int | None, n=2000, seed=0) -> float:
    correct = tot = 0
    for s in range(n // 500):
        x, y = image_batch(500, 10_000 + s, seed=seed)
        if bits is not None:
            fmt = FixedPointFormat(bits, bits)  # inputs in [0, 1)
            x = fmt.dequantize(fmt.quantize(x))
        logits = linear_classifier_forward(params, x, ctx)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y))
        tot += 500
    return correct / tot


def rows() -> list[tuple[str, float, str]]:
    params, ctx = train_linear()
    ref = accuracy(params, ctx, None)
    out = [("fig4/reference_fp32", round(ref, 4), "full precision")]
    for bits in range(1, 9):
        acc = accuracy(params, ctx, bits)
        out.append((f"fig4/bits_{bits}", round(acc, 4), f"delta={acc - ref:+.4f}"))
    return out
