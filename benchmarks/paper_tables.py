"""Analytic reproduction of every derivable paper table/figure.

  Fig. 4/6  -> accuracy vs input bits (measured, synthetic MNIST stand-in;
               see accuracy_vs_bits.py)
  Fig. 5    -> linear-classifier LUT-size vs shift-add tradeoff
  Fig. 7    -> MLP tradeoff (binary16 bitplane + full-bits points)
  Fig. 8    -> CNN tradeoff
  inline    -> the paper's quoted numbers (56 LUTs/17.5 MiB/168 evals/...,
               2320 LUTs/162.6 MiB/14,652,918 adds, ...)
"""
from __future__ import annotations

from repro.core.analysis import (
    CNN_CONVS,
    CNN_DENSE,
    LINEAR_CLASSIFIER,
    MLP,
    MiB,
    conv_layer_cost,
    figure_curve,
    network_cost,
    paper_claims,
)
from repro.core.quantize import FixedPointFormat, Float16Format


def rows() -> list[tuple[str, float, str]]:
    out = []
    claims = paper_claims()
    lin = claims["linear_m14"]
    out.append(("paper/linear_m14_tables", lin["tables"], "paper=56"))
    out.append(("paper/linear_m14_MiB", round(lin["mib"], 2), "paper=17.5"))
    out.append(("paper/linear_m14_evals", lin["evals"], "paper=168"))
    out.append(("paper/linear_m14_adds", lin["shift_adds"], "paper~1650"))
    out.append(
        ("paper/linear_m1_KiB", round(claims["linear_m1"]["kib"], 1), "paper=30.6")
    )
    mlp = claims["mlp_bitplane"]
    out.append(("paper/mlp_tables", mlp["tables"], "paper=2320"))
    out.append(("paper/mlp_MiB", round(mlp["mib"], 1), "paper=162.6"))
    out.append(("paper/mlp_adds", mlp["shift_adds"], "paper=14652918 (exact)"))
    out.append(
        ("paper/mlp_full_adds", claims["mlp_full"]["adds"], "paper=1330678 (exact)")
    )
    out.append(("paper/cnn_MiB", round(claims["cnn_bitplane"]["mib"], 0), "paper~400"))
    out.append(("paper/mlp_ref_madds", claims["mlp_ref_madds"], "paper=1332224"))

    # Fig. 5: linear classifier, 3-bit fixed point, both modes
    for r in figure_curve(LINEAR_CLASSIFIER, FixedPointFormat(3, 3)):
        out.append(
            (
                f"fig5/{r['mode']}_m{r['chunk']}",
                r["shift_adds"],
                f"lut_bytes={r['bytes']}",
            )
        )
    # Fig. 7: MLP fp16
    for r in figure_curve(MLP, Float16Format())[:8]:
        out.append(
            (
                f"fig7/{r['mode']}_m{r['chunk']}",
                r["shift_adds"],
                f"lut_MiB={r['bytes'] / MiB:.1f}",
            )
        )
    # Fig. 8: CNN = conv layers (shared tables) + dense layers
    for m in (1, 2, 3):
        dense = network_cost(CNN_DENSE, Float16Format(), m)
        convs = [
            conv_layer_cost(q, p, pos, Float16Format(), m) for q, p, pos in CNN_CONVS
        ]
        total_b = dense["bytes"] + sum(c["bytes"] for c in convs)
        total_a = dense["shift_adds"] + sum(c["shift_adds"] for c in convs)
        out.append((f"fig8/bitplane_m{m}", total_a, f"lut_MiB={total_b / MiB:.1f}"))
    return out
