"""Benchmark harness: one section per paper table/figure + kernel/serving
micro-benches + the roofline table from the dry-run.

Prints ``name,value,derived`` CSV (value is us_per_call for kern/serve
sections, the paper's quantity elsewhere).
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--sections",
        default="paper,accuracy,kernels,serving,roofline",
        help="comma list: paper,accuracy,kernels,serving,roofline",
    )
    args = ap.parse_args()
    sections = args.sections.split(",")
    all_rows: list[tuple[str, float, str]] = []

    if "paper" in sections:
        from benchmarks.paper_tables import rows as paper_rows

        all_rows += paper_rows()
    if "accuracy" in sections:
        from benchmarks.accuracy_vs_bits import rows as acc_rows

        all_rows += acc_rows()
    if "kernels" in sections:
        from benchmarks.kernels import rows as kern_rows

        all_rows += kern_rows()
    if "serving" in sections:
        from benchmarks.serving import rows as serve_rows

        all_rows += serve_rows()
    if "roofline" in sections:
        from benchmarks.roofline_report import rows as roof_rows

        all_rows += roof_rows()

    print("name,value,derived")
    for name, value, derived in all_rows:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
