"""Render the roofline table from results/dryrun.json (§Roofline source).

CLI (used by the CI bench-gate to publish the roofline artifact):

  PYTHONPATH=src python -m repro.launch.dryrun --arch whisper_base \
      --shape train_4k --mesh single
  PYTHONPATH=src python benchmarks/roofline_report.py --out ROOFLINE.json

emits the same ``{"name", "value", "unit"}`` row list as the other
benches (value = roofline fraction, -1 for skipped/failed cells), plus
the EXPERIMENTS.md markdown table with ``--markdown``.

LUT-serving cells (``params_mode == "lut"`` dryrun records) additionally
emit a per-row gather-vs-accumulate decomposition: the LUT decode step is
two phases — table-row *gather* (pure HBM traffic: ``planes x chunks``
rows of ``p`` table elements per token) and shift-add *accumulate* (pure
compute: one add per gathered element) — and the analytic split of the
cell's roofline into those phases says which side a tiling or a narrow
table format can still buy time on.
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")


def lut_decomposition(arch: str, tokens: int) -> dict:
    """Analytic gather-vs-accumulate split of ``tokens`` decode tokens
    through ``arch``'s LUT-converted projections (uniform chunk-1 plan, the
    dryrun's conversion).  Gather is HBM-bound (bytes of table rows
    touched), accumulate is compute-bound (one shift-add per gathered
    element); both in seconds at the chip peaks ``hlo_analysis`` uses."""
    from repro.configs.base import get_config
    from repro.core.lut import plane_scales
    from repro.core.planner import plan_model
    from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS
    from repro.models.model import model_specs
    from repro.models.params import abstract_params

    cfg = get_config(arch)
    params = abstract_params(model_specs(cfg))
    mplan = plan_model(params, float("inf"), max_chunk=1)
    gather_bytes = accum_ops = 0.0
    for key, plan in mplan.layers.items():
        copies = mplan.copies.get(key, 1)
        rows = len(plane_scales(plan)) * plan.num_chunks  # gathers per token
        elems = rows * plan.out_features
        gather_bytes += copies * elems * max(1, plan.storage_bits // 8)
        accum_ops += copies * elems
    return {
        "gather_bytes": tokens * gather_bytes,
        "accumulate_ops": tokens * accum_ops,
        "gather_s": tokens * gather_bytes / HBM_BW,
        "accumulate_s": tokens * accum_ops / PEAK_FLOPS,
    }


def _cell_tokens(shape: str) -> int:
    """Decoded/prefilled tokens a dryrun cell pushes through the model."""
    from repro.launch.inputs import shape_case

    case = shape_case(shape)
    if case.kind == "decode":
        return case.global_batch
    return case.global_batch * case.seq_len


def load(path: str = RESULTS) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def rows(path: str = RESULTS) -> list[tuple[str, float, str]]:
    out = []
    for r in sorted(load(path), key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        key = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "ok":
            t = r["terms"]
            out.append(
                (
                    key,
                    round(t["roofline_fraction"], 4),
                    f"dom={t['dominant']} c={t['compute_s']:.4f} "
                    f"m={t['memory_s']:.4f} x={t['collective_s']:.4f} "
                    f"useful={r['useful_flops_ratio']:.2f}",
                )
            )
            if r.get("params_mode") == "lut" and r.get("kind") != "train":
                d = lut_decomposition(r["arch"], _cell_tokens(r["shape"]))
                out.append(
                    (
                        f"{key}/gather_s",
                        round(d["gather_s"], 6),
                        f"{d['gather_bytes']:.3e} B of table rows "
                        f"(cell memory_s={t['memory_s']:.4f})",
                    )
                )
                out.append(
                    (
                        f"{key}/accumulate_s",
                        round(d["accumulate_s"], 6),
                        f"{d['accumulate_ops']:.3e} shift-adds "
                        f"(cell compute_s={t['compute_s']:.4f})",
                    )
                )
        else:
            why = r.get("reason", r.get("error", ""))[:60]
            out.append((key, -1.0, f"{r.get('status')}: {why}"))
    return out


def markdown_table(records: list[dict]) -> str:
    """EXPERIMENTS.md §Roofline table."""
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant "
        "| roofline frac | useful FLOPs | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") == "ok":
            t = r["terms"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
                f"| {t['collective_s']:.4f} | {t['dominant']} "
                f"| {t['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} | |"
            )
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | – | – | – | – | – | – "
                f"| {r.get('status')}: {r.get('reason', r.get('error', ''))[:50]} |"
            )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS,
                    help="dryrun records to render (results/dryrun.json)")
    ap.add_argument("--out", default=None, help="write JSON rows to this path")
    ap.add_argument("--markdown", action="store_true",
                    help="print the EXPERIMENTS.md table instead of JSON rows")
    args = ap.parse_args()
    if args.markdown:
        print(markdown_table(load(args.results)))
        return
    payload = [
        {"name": name, "value": value, "unit": unit}
        for name, value, unit in rows(args.results)
    ]
    text = json.dumps(payload, indent=1)
    print(text)
    if args.out:
        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
