"""Render the roofline table from results/dryrun.json (§Roofline source).

CLI (used by the CI bench-gate to publish the roofline artifact):

  PYTHONPATH=src python -m repro.launch.dryrun --arch whisper_base \
      --shape train_4k --mesh single
  PYTHONPATH=src python benchmarks/roofline_report.py --out ROOFLINE.json

emits the same ``{"name", "value", "unit"}`` row list as the other
benches (value = roofline fraction, -1 for skipped/failed cells), plus
the EXPERIMENTS.md markdown table with ``--markdown``.
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")


def load(path: str = RESULTS) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def rows(path: str = RESULTS) -> list[tuple[str, float, str]]:
    out = []
    for r in sorted(load(path), key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        key = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "ok":
            t = r["terms"]
            out.append((
                key,
                round(t["roofline_fraction"], 4),
                f"dom={t['dominant']} c={t['compute_s']:.4f} m={t['memory_s']:.4f} "
                f"x={t['collective_s']:.4f} useful={r['useful_flops_ratio']:.2f}",
            ))
        else:
            out.append((key, -1.0, f"{r.get('status')}: {r.get('reason', r.get('error',''))[:60]}"))
    return out


def markdown_table(records: list[dict]) -> str:
    """EXPERIMENTS.md §Roofline table."""
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant "
        "| roofline frac | useful FLOPs | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") == "ok":
            t = r["terms"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
                f"| {t['collective_s']:.4f} | {t['dominant']} "
                f"| {t['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} | |"
            )
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | – | – | – | – | – | – "
                f"| {r.get('status')}: {r.get('reason', r.get('error', ''))[:50]} |"
            )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS,
                    help="dryrun records to render (results/dryrun.json)")
    ap.add_argument("--out", default=None, help="write JSON rows to this path")
    ap.add_argument("--markdown", action="store_true",
                    help="print the EXPERIMENTS.md table instead of JSON rows")
    args = ap.parse_args()
    if args.markdown:
        print(markdown_table(load(args.results)))
        return
    payload = [
        {"name": name, "value": value, "unit": unit}
        for name, value, unit in rows(args.results)
    ]
    text = json.dumps(payload, indent=1)
    print(text)
    if args.out:
        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
