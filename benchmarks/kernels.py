"""Kernel micro-benchmarks (CPU wall-time; interpret-mode Pallas).

Timing on this host is NOT the perf deliverable (that's the §Roofline
analysis from the dry-run); these benches verify the execution paths run
and give relative cost context between the LUT modes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.lut import LUTPlan, apply_luts, build_luts, pack_codes, plane_scales
from repro.core.quantize import Float16Format
from repro.kernels.binary_matmul.ops import binary_matmul
from repro.kernels.lut_affine.ops import lut_affine


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


SHAPES = [(32, 256, 256, 1), (8, 512, 512, 1)]
TINY_SHAPES = [(4, 32, 32, 1)]  # CI smoke: seconds, not minutes


def rows(tiny: bool = False) -> list[tuple[str, float, str]]:
    out = []
    fmt = Float16Format(signed=True)
    for B, q, p, m in (TINY_SHAPES if tiny else SHAPES):
        plan = LUTPlan(q, p, m, fmt)
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (q, p)) / q**0.5
        x = jax.random.normal(key, (B, q))
        tables = build_luts(W, plan)
        codes = pack_codes(x, plan)
        scales = jnp.asarray(plane_scales(plan), jnp.float32)

        t_ref = _time(
            jax.jit(lambda c, t: apply_luts(t, c, plan)), codes, tables
        )
        t_kern = _time(
            lambda c, t: lut_affine(c, t, scales, interpret=True), codes, tables
        )
        t_mat = _time(jax.jit(lambda a, w: a @ w), x, W)
        tag = f"B{B}_q{q}_p{p}_m{m}"
        out.append((f"kern/lut_affine_jnp_{tag}", round(t_ref, 1), "us/call"))
        out.append((f"kern/lut_affine_pallas_{tag}", round(t_kern, 1), "us/call interpret"))
        out.append((f"kern/matmul_ref_{tag}", round(t_mat, 1), "us/call"))
        if m == 1:
            planes = codes.astype(jnp.int8)
            t_bmm = _time(
                lambda pl, w: binary_matmul(pl, w, scales, interpret=True), planes, W
            )
            out.append((f"kern/binary_matmul_{tag}", round(t_bmm, 1), "us/call interpret"))
    return out


def main():
    """CI smoke-bench entry point: run (optionally tiny) shapes, emit JSON."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="single small shape (CI smoke-bench)")
    ap.add_argument("--out", default=None, help="write JSON rows to this path")
    args = ap.parse_args()
    payload = [
        {"name": name, "value": value, "unit": unit}
        for name, value, unit in rows(tiny=args.tiny)
    ]
    text = json.dumps(payload, indent=1)
    print(text)
    if args.out:
        import os

        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
