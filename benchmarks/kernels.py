"""Kernel micro-benchmarks (CPU wall-time; interpret-mode Pallas).

Timing on this host is NOT the perf deliverable (that's the §Roofline
analysis from the dry-run); these benches verify the execution paths run
and give relative cost context between the LUT modes.
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.core.lut import LUTPlan, apply_luts, build_luts, pack_codes, plane_scales
from repro.core.lut_tl1 import TL1Plan, apply_tl1, build_tl1_tables, quantize_acts
from repro.core.quantize import Float16Format
from repro.kernels.binary_matmul.ops import binary_matmul
from repro.kernels.lut_affine.ops import lut_affine, lut_affine_grouped
from repro.kernels.lut_tl1.ops import lut_tl1, lut_tl1_grouped


def _time(fn, *args, iters=5) -> float:
    """Median per-call wall time in us.  Each iteration blocks on its own
    result — timing the loop with a single trailing ``block_until_ready``
    lets async dispatch overlap iterations and understates the mean."""
    jax.block_until_ready(fn(*args))  # compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return statistics.median(times) * 1e6  # us


SHAPES = [(32, 256, 256, 1), (8, 512, 512, 1)]
TINY_SHAPES = [(4, 32, 32, 1)]  # CI smoke: seconds, not minutes


def rows(tiny: bool = False) -> list[tuple[str, float, str]]:
    out = []
    fmt = Float16Format(signed=True)
    # tiny shapes are cheap: many iters so the CI gate medians are stable
    iters = 25 if tiny else 5
    for B, q, p, m in (TINY_SHAPES if tiny else SHAPES):
        plan = LUTPlan(q, p, m, fmt)
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (q, p)) / q**0.5
        x = jax.random.normal(key, (B, q))
        tables = build_luts(W, plan)
        codes = pack_codes(x, plan)
        scales = jnp.asarray(plane_scales(plan), jnp.float32)

        t_ref = _time(
            jax.jit(lambda c, t: apply_luts(t, c, plan)), codes, tables, iters=iters
        )
        t_kern = _time(
            lambda c, t: lut_affine(c, t, scales, interpret=True),
            codes,
            tables,
            iters=iters,
        )
        t_mat = _time(jax.jit(lambda a, w: a @ w), x, W, iters=iters)
        tag = f"B{B}_q{q}_p{p}_m{m}"
        out.append((f"kern/lut_affine_jnp_{tag}", round(t_ref, 1), "us/call"))
        out.append(
            (f"kern/lut_affine_pallas_{tag}", round(t_kern, 1), "us/call interpret")
        )
        out.append((f"kern/matmul_ref_{tag}", round(t_mat, 1), "us/call"))

        # QKV-style fusion: 3 same-shape projections, one grid vs 3 dispatches
        tables3 = jnp.stack([tables, tables, tables])
        t_grp = _time(
            lambda c, t: lut_affine_grouped(c, t, scales, interpret=True),
            codes,
            tables3,
            iters=iters,
        )
        t_3x = _time(
            lambda c, t: jnp.stack(
                [lut_affine(c, t[g], scales, interpret=True) for g in range(3)]
            ),
            codes,
            tables3,
            iters=iters,
        )
        out.append(
            (f"kern/lut_affine_grouped3_{tag}", round(t_grp, 1), "us/call interpret")
        )
        out.append(
            (f"kern/lut_affine_dispatch3_{tag}", round(t_3x, 1), "us/call interpret")
        )
        if m == 1:
            planes = codes.astype(jnp.int8)
            t_bmm = _time(
                lambda pl, w: binary_matmul(pl, w, scales, interpret=True),
                planes,
                W,
                iters=iters,
            )
            out.append(
                (f"kern/binary_matmul_{tag}", round(t_bmm, 1), "us/call interpret")
            )

        # TL1 activation-side family at the same (B, q, p) shape: ternary
        # weights packed as base-3 pair indices, per-token 9-entry LUT
        tl1_plan = TL1Plan(q, p)
        tl1_tables, tl1_scale = build_tl1_tables(W)
        tl1_codes, act_scale = quantize_acts(x, tl1_plan)
        t_tl1_ref = _time(
            jax.jit(
                lambda a, t: apply_tl1(t, a, tl1_plan, scale=tl1_scale)
            ),
            x,
            tl1_tables,
            iters=iters,
        )
        t_tl1 = _time(
            lambda c, t: lut_tl1(c, t, act_scale, tl1_scale, interpret=True),
            tl1_codes,
            tl1_tables,
            iters=iters,
        )
        tl1_tables3 = jnp.stack([tl1_tables] * 3)
        tl1_scale3 = jnp.stack([tl1_scale] * 3)
        t_tl1_grp = _time(
            lambda c, t: lut_tl1_grouped(c, t, act_scale, tl1_scale3,
                                         interpret=True),
            tl1_codes,
            tl1_tables3,
            iters=iters,
        )
        out.append((f"kern/lut_tl1_jnp_{tag}", round(t_tl1_ref, 1), "us/call"))
        out.append(
            (f"kern/lut_tl1_pallas_{tag}", round(t_tl1, 1), "us/call interpret")
        )
        out.append(
            (f"kern/lut_tl1_grouped3_{tag}", round(t_tl1_grp, 1),
             "us/call interpret")
        )
    return out


def main():
    """CI smoke-bench entry point: run (optionally tiny) shapes, emit JSON."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="single small shape (CI smoke-bench)")
    ap.add_argument("--out", default=None, help="write JSON rows to this path")
    args = ap.parse_args()
    payload = [
        {"name": name, "value": value, "unit": unit}
        for name, value, unit in rows(tiny=args.tiny)
    ]
    text = json.dumps(payload, indent=1)
    print(text)
    if args.out:
        import os

        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
